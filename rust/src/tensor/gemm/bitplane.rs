//! The bit-plane weight representation and its GEMM (DESIGN.md §8, §13).
//! Construction and plane bookkeeping are backend-independent; the column
//! kernel dispatches between the scalar walk and its bitwise-identical
//! AVX2 widening (`kernel_scalar`/`kernel_avx2::bitplane_columns`).

use crate::quant::packed::PackedCodes;

use super::Backend;
#[cfg(target_arch = "x86_64")]
use super::kernel_avx2;
use super::kernel_scalar;

/// A quantized weight matrix held as sign-split per-plane bitsets, laid out
/// for GEMM: for each plane `b` and output column `j`, one row of
/// `words = ceil(K/64)` u64s whose bit `k` says weight `(k, j)` has bit `b`
/// of its magnitude set (in `pos` for positive codes, `neg` for negative).
///
/// Constructed from the `quant::packed` integer codes; planes at or above
/// `bits` (trimmed by §3.3 re-quantization) are never materialized, and
/// empty surviving planes are skipped per multiply via `plane_pop`.
#[derive(Debug, Clone)]
pub struct BitPlaneMatrix {
    k: usize,
    n: usize,
    words: usize,
    bits: usize,
    delta: f32,
    pos: Vec<u64>,
    neg: Vec<u64>,
    plane_pop: Vec<u64>,
}

impl BitPlaneMatrix {
    /// Build from raw signed codes stored row-major `[K, N]` (the HWIO /
    /// `[in, out]` flattening). `bits` caps the materialized planes; `delta`
    /// is the LSB step δ = s/(2^bits − 1).
    pub fn from_codes(codes: &[i16], k: usize, n: usize, bits: usize, delta: f32) -> Self {
        assert_eq!(codes.len(), k * n, "codes are not K×N");
        let words = k.div_ceil(64).max(1);
        let bits = bits.min(16);
        let mut pos = vec![0u64; bits * n * words];
        let mut neg = vec![0u64; bits * n * words];
        for (e, &c) in codes.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let kk = e / n;
            let j = e % n;
            let (planes, mut mag) =
                if c > 0 { (&mut pos, c as u64) } else { (&mut neg, (c as i64).unsigned_abs()) };
            let word = kk >> 6;
            let bit = 1u64 << (kk & 63);
            while mag != 0 {
                let b = mag.trailing_zeros() as usize;
                if b >= bits {
                    break; // only higher bits remain
                }
                planes[(b * n + j) * words + word] |= bit;
                mag &= mag - 1;
            }
        }
        let plane_pop = (0..bits)
            .map(|b| {
                let span = b * n * words..(b + 1) * n * words;
                let ones = |w: &u64| w.count_ones() as u64;
                pos[span.clone()].iter().map(ones).sum::<u64>()
                    + neg[span].iter().map(ones).sum::<u64>()
            })
            .collect();
        BitPlaneMatrix { k, n, words, bits, delta, pos, neg, plane_pop }
    }

    /// Build from a packed layer: the trailing weight-shape axis is the
    /// output dimension (cout for HWIO convs, out for `[in, out]` dense).
    ///
    /// Mid-training codes can run one bit wider than the layer's nominal
    /// precision (the §3.3 n+1 growth: continuous planes reach 2.0), so the
    /// materialized plane count covers the widest code actually present —
    /// the product always equals `p.dequantize()`, never a truncation.
    pub fn from_packed(p: &PackedCodes) -> Self {
        let n = p.wshape.last().copied().unwrap_or(1).max(1);
        let k = p.elems() / n;
        let widest = p
            .codes
            .iter()
            .map(|c| 16 - c.unsigned_abs().leading_zeros() as usize)
            .max()
            .unwrap_or(0);
        Self::from_codes(&p.codes, k, n, p.bits.max(widest), p.delta() as f32)
    }

    pub fn k(&self) -> usize {
        self.k
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Active (materialized) plane count.
    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Total set weight bits — the exact work the multiply performs.
    pub fn nnz_bits(&self) -> u64 {
        self.plane_pop.iter().sum()
    }

    /// Planes that actually hold bits (empty ones are skipped wholesale).
    pub fn occupied_planes(&self) -> usize {
        self.plane_pop.iter().filter(|&&p| p != 0).count()
    }

    /// Heap bytes this matrix keeps resident (the bitsets dominate a
    /// servable's footprint) — what the registry's byte-budgeted LRU
    /// charges a cached `BoundPlan` for.
    pub fn resident_bytes(&self) -> usize {
        (self.pos.len() + self.neg.len() + self.plane_pop.len()) * std::mem::size_of::<u64>()
    }

    /// `C = Xᵀ·W·δ` over the bitsets: `xt` is X *transposed*, `[K, M]`
    /// row-major (column `k` of X contiguous over the M batch rows), the
    /// result is `[N, M]` (output-major; `transpose` restores `[M, N]`).
    ///
    /// Cost ∝ M × set bits: each set bit triggers one length-M fused
    /// scale-add of a contiguous activation column, planes with zero
    /// popcount cost one branch.
    pub fn matmul_t(&self, xt: &[f32], m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; self.n * m];
        self.matmul_t_into(&mut out, xt, m);
        out
    }

    /// [`BitPlaneMatrix::matmul_t`] into a caller-owned `[N, M]` buffer
    /// (zeroed first — recycled arena scratch carries stale values). The
    /// parallel column split honors the thread-local cap, so a capped
    /// serving worker runs it allocation-free. The backend is resolved
    /// once, here, before any worker threads spawn (fresh TLS on workers
    /// must not re-dispatch), and the per-element result is bitwise
    /// identical on both backends and at any column split.
    pub fn matmul_t_into(&self, out: &mut [f32], xt: &[f32], m: usize) {
        assert_eq!(xt.len(), self.k * m, "Xᵀ is not K×M");
        assert_eq!(out.len(), self.n * m, "out is not N×M");
        out.fill(0.0);
        if m == 0 || self.nnz_bits() == 0 {
            return;
        }
        let backend = super::active_backend();
        let work = self.nnz_bits() as usize * m;
        let workers = super::worker_count(work).min(self.n.max(1));
        if workers <= 1 {
            self.columns_into(out, xt, m, 0, backend);
            return;
        }
        let cols_per = self.n.div_ceil(workers);
        std::thread::scope(|s| {
            for (ci, chunk) in out.chunks_mut(cols_per * m).enumerate() {
                s.spawn(move || self.columns_into(chunk, xt, m, ci * cols_per, backend));
            }
        });
    }

    /// Accumulate output columns `[j0, j0 + chunk.len()/m)` into `chunk`
    /// on the given backend.
    fn columns_into(&self, chunk: &mut [f32], xt: &[f32], m: usize, j0: usize, backend: Backend) {
        match backend {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: Avx2Fma is only ever selected when detection (or
            // `with_backend`'s availability assert) confirmed AVX2.
            Backend::Avx2Fma => unsafe {
                kernel_avx2::bitplane_columns(
                    chunk,
                    xt,
                    m,
                    j0,
                    self.bits,
                    self.n,
                    self.words,
                    self.delta,
                    &self.pos,
                    &self.neg,
                    &self.plane_pop,
                )
            },
            _ => kernel_scalar::bitplane_columns(
                chunk,
                xt,
                m,
                j0,
                self.bits,
                self.n,
                self.words,
                self.delta,
                &self.pos,
                &self.neg,
                &self.plane_pop,
            ),
        }
    }
}
