//! AVX2/FMA backend: register-blocked packed-panel dense microkernel and
//! the 256-bit bit-plane column kernel (DESIGN.md §13).
//!
//! Safety contract for the whole module: every `#[target_feature]`
//! function is only reachable through the dispatch in `gemm/mod.rs` /
//! `gemm/bitplane.rs`, which selects `Backend::Avx2Fma` solely when
//! `is_x86_feature_detected!` reported both features (or `with_backend`
//! asserted availability). Pointer arithmetic stays inside the bounds the
//! packing layouts and the callers' slice asserts establish.
//!
//! Determinism: the dense kernel gives every output element a fixed
//! K-accumulation order — sequential FMA into one register lane within
//! each KC block, one `c += acc` per block — that depends only on K,
//! because a lane's sums involve only its own A row (broadcast) and B
//! column (fixed vector lane) and the zero padding of edge tiles never
//! reorders real elements. Row partitions (threads, shards) and the batch
//! size cannot change any element's order, so SIMD results are bitwise
//! reproducible across all of them. The bit-plane kernel goes further:
//! unfused vector mul-then-add in the scalar walk's exact per-element
//! order makes it bitwise equal to the scalar backend itself.

use std::arch::x86_64::{
    __m256i, _mm256_add_ps, _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_loadu_si256,
    _mm256_maskload_ps, _mm256_maskstore_ps, _mm256_mul_ps, _mm256_set1_ps, _mm256_setzero_ps,
    _mm256_storeu_ps,
};

use super::pack::{self, KC, MR, NR};

/// Writeback masks for partial tiles: loading 8 lanes at offset `8 - nr`
/// yields `nr` high-bit-set lanes followed by zeros — exactly the lanes
/// `maskload`/`maskstore` touch.
static TAIL: [i32; 16] = [-1, -1, -1, -1, -1, -1, -1, -1, 0, 0, 0, 0, 0, 0, 0, 0];

/// Mask enabling the first `lanes` (1..=8) of a 256-bit f32 vector.
///
/// # Safety
/// Caller must be in AVX2-enabled code; `lanes` must be in 1..=8.
#[inline(always)]
unsafe fn tail_mask(lanes: usize) -> __m256i {
    debug_assert!((1..=8).contains(&lanes));
    _mm256_loadu_si256(TAIL.as_ptr().add(8 - lanes) as *const __m256i)
}

/// Dense GEMM driver: `C[M,N] += A·B` with A and B given as strided views
/// (element `(i, kk)` of A at `a[i·a_rs + kk·a_cs]`, element `(kk, j)` of
/// B at `b[kk·b_rs + j·b_cs]`), so the transposed entry points pack their
/// operands directly instead of materializing transposes.
///
/// Packs all of B once into the thread-local scratch, then fans out over
/// MR-aligned row chunks; each worker packs its own A tiles on the stack.
/// The backend was resolved by the caller *before* this call, so the
/// worker threads (fresh TLS) never re-dispatch.
#[allow(clippy::too_many_arguments)]
pub(super) fn gemm(
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    a_rs: usize,
    a_cs: usize,
    b: &[f32],
    b_rs: usize,
    b_cs: usize,
) {
    pack::with_pack_buf(pack::packed_b_elems(k, n), |pb| {
        pack::pack_b(pb, b, b_rs, b_cs, k, n);
        let pb = &*pb;
        let workers = super::worker_count(m * k * n).min(m.div_ceil(MR));
        if workers <= 1 {
            return gemm_rows(c, a, a_rs, a_cs, pb, m, k, n);
        }
        // Round chunks to MR so only the last chunk carries a partial tile;
        // the split cannot change results (see module docs).
        let rows_per = m.div_ceil(workers).div_ceil(MR) * MR;
        std::thread::scope(|s| {
            for (ci, cchunk) in c.chunks_mut(rows_per * n).enumerate() {
                let rows = cchunk.len() / n;
                let abase = &a[ci * rows_per * a_rs..];
                s.spawn(move || gemm_rows(cchunk, abase, a_rs, a_cs, pb, rows, k, n));
            }
        });
    });
}

/// One worker's share: sweep KC blocks of K; per block pack each MR-row
/// A tile once and run it across every B panel, accumulating into C.
fn gemm_rows(
    c: &mut [f32],
    a: &[f32],
    a_rs: usize,
    a_cs: usize,
    pb: &[f32],
    m: usize,
    k: usize,
    n: usize,
) {
    let mut apack = [0.0f32; MR * KC];
    let panels = n.div_ceil(NR);
    for kb in (0..k).step_by(KC) {
        let kc = KC.min(k - kb);
        for i0 in (0..m).step_by(MR) {
            let mr = MR.min(m - i0);
            pack::pack_a_tile(&mut apack, a, a_rs, a_cs, i0, mr, kb, kc);
            for jp in 0..panels {
                let j0 = jp * NR;
                let nr = NR.min(n - j0);
                // panel jp stores k contiguously: the kb..kb+kc rows are one slice
                let bpanel = &pb[jp * k * NR + kb * NR..][..kc * NR];
                // SAFETY: dispatch guaranteed AVX2+FMA; apack/bpanel hold
                // kc full rows; C indices stay below m×n by construction.
                unsafe { mk8x8(c, i0, j0, n, mr, nr, &apack, bpanel, kc) };
            }
        }
    }
}

/// The 8×8 register-blocked microkernel: 8 accumulator vectors (one per A
/// row), per k one B-panel vector load + 8 broadcast-FMAs, then one add
/// per row into C (masked when the tile is a column edge).
///
/// # Safety
/// AVX2+FMA must be available. `apack` holds `kc` rows of MR floats,
/// `bpanel` holds `kc` rows of NR floats, and rows `i0..i0+mr` of the
/// row-major `[?, n]` matrix `c` must have `nr` in-bounds columns at `j0`.
#[target_feature(enable = "avx2", enable = "fma")]
#[allow(clippy::too_many_arguments)]
unsafe fn mk8x8(
    c: &mut [f32],
    i0: usize,
    j0: usize,
    n: usize,
    mr: usize,
    nr: usize,
    apack: &[f32; MR * KC],
    bpanel: &[f32],
    kc: usize,
) {
    let mut acc = [_mm256_setzero_ps(); MR];
    let ap = apack.as_ptr();
    let bp = bpanel.as_ptr();
    for kk in 0..kc {
        let bv = _mm256_loadu_ps(bp.add(kk * NR));
        let arow = ap.add(kk * MR);
        for (i, accv) in acc.iter_mut().enumerate() {
            let av = _mm256_set1_ps(*arow.add(i));
            *accv = _mm256_fmadd_ps(av, bv, *accv);
        }
    }
    if nr == NR {
        for (i, &accv) in acc.iter().take(mr).enumerate() {
            let cp = c.as_mut_ptr().add((i0 + i) * n + j0);
            _mm256_storeu_ps(cp, _mm256_add_ps(_mm256_loadu_ps(cp), accv));
        }
    } else {
        let mask = tail_mask(nr);
        for (i, &accv) in acc.iter().take(mr).enumerate() {
            let cp = c.as_mut_ptr().add((i0 + i) * n + j0);
            let cur = _mm256_maskload_ps(cp, mask);
            _mm256_maskstore_ps(cp, mask, _mm256_add_ps(cur, accv));
        }
    }
}

/// AVX2 bit-plane column kernel: the scalar walk with each set bit's
/// length-M scale-add widened to 256-bit lanes over the batch dimension.
///
/// Uses vector `mul` + `add` (NOT FMA) in the scalar walk's exact
/// per-element order, so results are **bitwise identical** to
/// `kernel_scalar::bitplane_columns` — serve logits do not move when
/// dispatch flips, and batched-vs-single stays exact (per-element order
/// never depends on M).
///
/// # Safety
/// AVX2 must be available; arguments must be a `BitPlaneMatrix`'s fields
/// with `chunk.len()` a multiple of `m` and `xt` of length `k·m`.
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
pub(super) unsafe fn bitplane_columns(
    chunk: &mut [f32],
    xt: &[f32],
    m: usize,
    j0: usize,
    bits: usize,
    n: usize,
    words: usize,
    delta: f32,
    pos: &[u64],
    neg: &[u64],
    plane_pop: &[u64],
) {
    let mfull = m & !(NR - 1);
    let tail = m - mfull;
    for (cj, col) in chunk.chunks_mut(m).enumerate() {
        let j = j0 + cj;
        for b in 0..bits {
            if plane_pop[b] == 0 {
                continue; // trimmed or regularized-away plane: free
            }
            let w2 = delta * (1u32 << b) as f32;
            for (planes, scale) in [(pos, w2), (neg, -w2)] {
                let sv = _mm256_set1_ps(scale);
                let row = &planes[(b * n + j) * words..][..words];
                for (wi, &word) in row.iter().enumerate() {
                    let mut wbits = word;
                    while wbits != 0 {
                        let kk = (wi << 6) + wbits.trailing_zeros() as usize;
                        wbits &= wbits - 1;
                        let src = xt.as_ptr().add(kk * m);
                        let dst = col.as_mut_ptr();
                        let mut o = 0;
                        while o < mfull {
                            let pv = _mm256_mul_ps(sv, _mm256_loadu_ps(src.add(o)));
                            let cv = _mm256_loadu_ps(dst.add(o));
                            _mm256_storeu_ps(dst.add(o), _mm256_add_ps(cv, pv));
                            o += NR;
                        }
                        if tail != 0 {
                            let mask = tail_mask(tail);
                            let pv = _mm256_mul_ps(sv, _mm256_maskload_ps(src.add(o), mask));
                            let cv = _mm256_maskload_ps(dst.add(o), mask);
                            _mm256_maskstore_ps(dst.add(o), mask, _mm256_add_ps(cv, pv));
                        }
                    }
                }
            }
        }
    }
}
