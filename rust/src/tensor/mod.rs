//! Host tensor substrate: dense f32/i32 arrays with shapes.
//!
//! The coordinator's own compute (bit-plane packing, precision adjustment,
//! HAWQ power iteration, data synthesis) runs on these; device compute goes
//! through `runtime::` artifacts. Deliberately small: row-major, owned
//! storage, just the ops the coordinator needs.

use anyhow::{bail, Result};

use crate::util::Pcg32;

pub mod gemm;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} needs {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn from_vec(data: Vec<f32>) -> Tensor {
        Tensor { shape: vec![data.len()], data }
    }

    /// Standard normal entries scaled by `std`.
    pub fn randn(shape: &[usize], std: f32, rng: &mut Pcg32) -> Tensor {
        let n = shape.iter().product();
        let data = (0..n).map(|_| rng.normal() * std).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    /// He/Kaiming init for a conv (HWIO) or dense ([in, out]) weight:
    /// N(0, sqrt(2 / fan_in)).
    pub fn he_init(shape: &[usize], rng: &mut Pcg32) -> Tensor {
        let fan_in: usize =
            if shape.len() > 1 { shape[..shape.len() - 1].iter().product() } else { shape[0] };
        Self::randn(shape, (2.0 / fan_in.max(1) as f32).sqrt(), rng)
    }

    pub fn uniform(shape: &[usize], lo: f32, hi: f32, rng: &mut Pcg32) -> Tensor {
        let n = shape.iter().product();
        let data = (0..n).map(|_| rng.range(lo, hi)).collect();
        Tensor { shape: shape.to_vec(), data }
    }

    // -- accessors -----------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Zero-copy contiguous row `i` of a `[rows, len]` view of the storage
    /// (e.g. one bit plane of a `[NB, *wshape]` tensor with `len = elems`).
    pub fn row(&self, i: usize, len: usize) -> &[f32] {
        &self.data[i * len..(i + 1) * len]
    }

    /// Mutable zero-copy row view; see [`Tensor::row`].
    pub fn row_mut(&mut self, i: usize, len: usize) -> &mut [f32] {
        &mut self.data[i * len..(i + 1) * len]
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn item(&self) -> Result<f32> {
        if self.data.len() != 1 {
            bail!("item() on tensor with {} elements", self.data.len());
        }
        Ok(self.data[0])
    }

    /// Reinterpret with a new shape of identical element count.
    pub fn reshaped(mut self, shape: &[usize]) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("cannot reshape {} elements to {:?}", self.data.len(), shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    // -- math ------------------------------------------------------------------

    pub fn dot(&self, other: &Tensor) -> f32 {
        debug_assert_eq!(self.len(), other.len());
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }

    pub fn norm2(&self) -> f32 {
        self.data.iter().map(|a| a * a).sum::<f32>().sqrt()
    }

    pub fn scale_inplace(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&v| f(v)).collect() }
    }
}

/// Dense row-major i32 tensor (labels).
#[derive(Debug, Clone, PartialEq)]
pub struct IntTensor {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl IntTensor {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<IntTensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} needs {} elements, got {}", shape, n, data.len());
        }
        Ok(IntTensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> IntTensor {
        IntTensor { shape: shape.to_vec(), data: vec![0; shape.iter().product()] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checks() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert_eq!(Tensor::zeros(&[4, 5]).len(), 20);
        assert_eq!(Tensor::scalar(3.0).item().unwrap(), 3.0);
    }

    #[test]
    fn he_init_variance() {
        let mut rng = Pcg32::seeded(0);
        let t = Tensor::he_init(&[3, 3, 16, 32], &mut rng);
        let n = t.len() as f32;
        let mean = t.data().iter().sum::<f32>() / n;
        let var = t.data().iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n;
        let want = 2.0 / (3.0 * 3.0 * 16.0);
        assert!((var / want - 1.0).abs() < 0.1, "var {var} want {want}");
    }

    #[test]
    fn reshape_and_math() {
        let t = Tensor::from_vec(vec![3.0, -4.0]);
        assert_eq!(t.norm2(), 5.0);
        assert_eq!(t.max_abs(), 4.0);
        let r = t.clone().reshaped(&[2, 1]).unwrap();
        assert_eq!(r.shape(), &[2, 1]);
        assert!(t.clone().reshaped(&[3]).is_err());
        assert_eq!(t.dot(&Tensor::from_vec(vec![1.0, 1.0])), -1.0);
    }

    #[test]
    fn row_views() {
        let mut t = Tensor::new(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap();
        assert_eq!(t.row(0, 3), &[1.0, 2.0, 3.0]);
        assert_eq!(t.row(1, 3), &[4.0, 5.0, 6.0]);
        t.row_mut(1, 3)[0] = 9.0;
        assert_eq!(t.data()[3], 9.0);
    }

    #[test]
    fn map_and_scale() {
        let mut t = Tensor::from_vec(vec![1.0, 2.0]);
        t.scale_inplace(2.0);
        assert_eq!(t.data(), &[2.0, 4.0]);
        assert_eq!(t.map(|v| v + 1.0).data(), &[3.0, 5.0]);
    }
}
