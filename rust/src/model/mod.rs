//! Model state management: named parameter maps + checkpointing.

pub mod checkpoint;
pub mod state;

pub use state::{momentum_slots, ModelState};
