//! Model state: the named tensor map the coordinator owns.
//!
//! Keys follow the shared convention in `python/compile/statespec.py`
//! (w:, wp:, wn:, mask:, scale:, bn:, pact:, step:, m: prefixes). The state
//! is initialized host-side from manifest metadata (He init for weights,
//! identity BN, zero momenta) and marshalled to/from device literals by
//! `runtime::exec`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::quant::bitplane::BitRep;
use crate::runtime::manifest::Manifest;
use crate::tensor::Tensor;
use crate::util::Pcg32;

#[derive(Debug, Clone, Default)]
pub struct ModelState {
    map: BTreeMap<String, Tensor>,
}

impl ModelState {
    pub fn new() -> ModelState {
        ModelState { map: BTreeMap::new() }
    }

    /// Fresh float-training state for a manifest: He-initialized weights,
    /// zero biases, identity BN, zero momenta for every trainable.
    pub fn init_fp(man: &Manifest, seed: u64) -> ModelState {
        let mut rng = Pcg32::new(seed, 101);
        let mut s = ModelState::new();
        for q in &man.qlayers {
            s.insert(format!("w:{}", q.name), Tensor::he_init(&q.shape, &mut rng));
        }
        for d in &man.dense_bias {
            let out = man
                .qlayers
                .iter()
                .find(|q| &q.name == d)
                .map(|q| *q.shape.last().unwrap())
                .unwrap_or(man.num_classes);
            s.insert(format!("w:{d}/b"), Tensor::zeros(&[out]));
        }
        for n in &man.bn_names {
            let c = man
                .qlayers
                .iter()
                .find(|q| &q.name == n)
                .map(|q| *q.shape.last().unwrap())
                .expect("bn without conv");
            s.insert(format!("bn:{n}/gamma"), Tensor::full(&[c], 1.0));
            s.insert(format!("bn:{n}/beta"), Tensor::zeros(&[c]));
            s.insert(format!("bn:{n}/mean"), Tensor::zeros(&[c]));
            s.insert(format!("bn:{n}/var"), Tensor::full(&[c], 1.0));
        }
        s
    }

    /// Add PACT clip parameters (one per activation site, init 6.0).
    pub fn add_pact(&mut self, man: &Manifest) {
        for site in &man.act_sites {
            self.insert(format!("pact:{site}"), Tensor::scalar(6.0));
        }
    }

    /// Add LSQ step sizes (one per layer, init from max|w|/levels at 8-bit).
    pub fn add_lsq_steps(&mut self, man: &Manifest) -> Result<()> {
        for q in &man.qlayers {
            let w = self.get(&format!("w:{}", q.name))?;
            let step = (w.max_abs() / 255.0).max(1e-6);
            self.insert(format!("step:{}", q.name), Tensor::scalar(step));
        }
        Ok(())
    }

    /// Ensure a zero momentum buffer `m:<key>` exists for every key an
    /// artifact wants (idempotent — call before running any train artifact).
    pub fn ensure_momenta(&mut self, wanted: &[(String, Vec<usize>)]) {
        for (name, shape) in wanted {
            if !self.map.contains_key(name) {
                self.insert(name.clone(), Tensor::zeros(shape));
            }
        }
    }

    /// Drop all momentum buffers (fresh optimizer for a new phase).
    pub fn reset_momenta(&mut self) {
        self.map.retain(|k, _| !k.starts_with("m:"));
    }

    // -- bit representation --------------------------------------------------

    /// Convert fp weights to the bit representation (start of BSQ training):
    /// installs wp:/wn:/mask:/scale: and removes the float master weights.
    pub fn to_bit_representation(&mut self, man: &Manifest, init_bits: usize) -> Result<()> {
        let bits = vec![init_bits; man.qlayers.len()];
        self.to_bit_representation_per_layer(man, &bits)
    }

    /// Per-layer initial precisions (the paper's ImageNet setting quantizes
    /// the leading convolutions at 8-bit and the rest at 6-bit).
    pub fn to_bit_representation_per_layer(
        &mut self,
        man: &Manifest,
        bits: &[usize],
    ) -> Result<()> {
        if bits.len() != man.qlayers.len() {
            bail!("{} init precisions for {} layers", bits.len(), man.qlayers.len());
        }
        for (q, &n) in man.qlayers.iter().zip(bits) {
            let key = format!("w:{}", q.name);
            let w = self.map.remove(&key).ok_or_else(|| anyhow!("missing {key}"))?;
            let rep = crate::quant::to_bitplanes(&w, n)?;
            self.install_bitrep(&q.name, rep);
        }
        self.reset_momenta();
        Ok(())
    }

    /// Materialize fp weights from the bit representation (for finetuning at
    /// a frozen scheme): installs w: keys, keeps the bit state intact.
    pub fn bit_to_fp_weights(&mut self, man: &Manifest) -> Result<()> {
        for q in &man.qlayers {
            let rep = self.bitrep(&q.name)?;
            let w = crate::quant::from_bitplanes(&rep);
            self.insert(format!("w:{}", q.name), w);
        }
        Ok(())
    }

    pub fn install_bitrep(&mut self, layer: &str, rep: BitRep) {
        self.insert(format!("wp:{layer}"), rep.wp);
        self.insert(format!("wn:{layer}"), rep.wn);
        self.insert(format!("mask:{layer}"), rep.mask);
        self.insert(format!("scale:{layer}"), Tensor::scalar(rep.scale));
    }

    /// Move a layer's bit representation *out* of the state without cloning
    /// the plane tensors — the allocation-free counterpart of [`Self::bitrep`]
    /// for the re-quantization pause (pair with `install_bitrep` to put the
    /// adjusted planes back). Fails without mutating if any piece is absent.
    pub fn take_bitrep(&mut self, layer: &str) -> Result<BitRep> {
        let scale = self.get(&format!("scale:{layer}"))?.item()?;
        for prefix in ["wp", "wn", "mask"] {
            let key = format!("{prefix}:{layer}");
            if !self.contains(&key) {
                bail!("state missing key {key:?}");
            }
        }
        Ok(BitRep {
            wp: self.remove(&format!("wp:{layer}")).unwrap(),
            wn: self.remove(&format!("wn:{layer}")).unwrap(),
            mask: self.remove(&format!("mask:{layer}")).unwrap(),
            scale,
        })
    }

    /// Borrowed view of a layer's bit representation (clones tensors; prefer
    /// [`Self::take_bitrep`] on hot paths — the plane clones dominate).
    pub fn bitrep(&self, layer: &str) -> Result<BitRep> {
        Ok(BitRep {
            wp: self.get(&format!("wp:{layer}"))?.clone(),
            wn: self.get(&format!("wn:{layer}"))?.clone(),
            mask: self.get(&format!("mask:{layer}"))?.clone(),
            scale: self.get(&format!("scale:{layer}"))?.item()?,
        })
    }

    /// Zero a layer's plane momentum buffers (`m:wp:` / `m:wn:`), if they
    /// exist. Re-quantization re-splits the codes into different planes, so
    /// stale per-plane momentum is meaningless after an install — both the
    /// synchronous pause and the overlapped install path (DESIGN.md §16)
    /// share this. Single fallible lookup per key: absent momenta (e.g.
    /// before the first train step of a phase) are simply skipped.
    pub fn zero_plane_momenta(&mut self, layer: &str) {
        for key in [format!("m:wp:{layer}"), format!("m:wn:{layer}")] {
            if let Some(t) = self.map.get_mut(&key) {
                t.data_mut().fill(0.0);
            }
        }
    }

    /// Per-layer active-bit counts, in manifest layer order.
    pub fn bits_by_layer(&self, man: &Manifest) -> Result<Vec<usize>> {
        man.qlayers
            .iter()
            .map(|q| {
                let m = self.get(&format!("mask:{}", q.name))?;
                Ok(m.data().iter().filter(|&&v| v != 0.0).count())
            })
            .collect()
    }

    // -- map plumbing ---------------------------------------------------------

    pub fn insert(&mut self, key: String, t: Tensor) {
        self.map.insert(key, t);
    }

    pub fn get(&self, key: &str) -> Result<&Tensor> {
        self.map.get(key).ok_or_else(|| anyhow!("state missing key {key:?}"))
    }

    pub fn get_mut(&mut self, key: &str) -> Result<&mut Tensor> {
        self.map.get_mut(key).ok_or_else(|| anyhow!("state missing key {key:?}"))
    }

    pub fn contains(&self, key: &str) -> bool {
        self.map.contains_key(key)
    }

    pub fn remove(&mut self, key: &str) -> Option<Tensor> {
        self.map.remove(key)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &Tensor)> {
        self.map.iter()
    }

    /// Validate that every `state`/input the artifact wants exists with the
    /// right shape (momenta are auto-created by `ensure_momenta` first).
    pub fn check_against(&self, inputs: &[crate::runtime::manifest::IoItem]) -> Result<()> {
        use crate::runtime::manifest::Role;
        for item in inputs {
            if item.role == Role::State {
                let t = self.get(&item.name)?;
                if t.shape() != item.shape.as_slice() {
                    bail!(
                        "state {}: shape {:?} ≠ artifact {:?}",
                        item.name,
                        t.shape(),
                        item.shape
                    );
                }
            }
        }
        Ok(())
    }
}

/// Momentum keys an artifact requires, derived from its input spec.
pub fn momentum_slots(inputs: &[crate::runtime::manifest::IoItem]) -> Vec<(String, Vec<usize>)> {
    inputs
        .iter()
        .filter(|i| i.name.starts_with("m:"))
        .map(|i| (i.name.clone(), i.shape.clone()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::bitplane::{packed_mask, NB};

    #[test]
    fn map_basics() {
        let mut s = ModelState::new();
        s.insert("a".into(), Tensor::scalar(1.0));
        assert!(s.contains("a"));
        assert!(s.get("b").is_err());
        assert_eq!(s.get("a").unwrap().item().unwrap(), 1.0);
        s.reset_momenta();
        assert_eq!(s.len(), 1);
        s.insert("m:a".into(), Tensor::scalar(0.0));
        s.reset_momenta();
        assert!(!s.contains("m:a"));
    }

    #[test]
    fn bitrep_roundtrip_via_state() {
        let mut s = ModelState::new();
        let w = Tensor::new(vec![4], vec![0.5, -0.25, 0.75, -1.0]).unwrap();
        let rep = crate::quant::to_bitplanes(&w, 8).unwrap();
        s.install_bitrep("conv1", rep);
        let back = s.bitrep("conv1").unwrap();
        assert_eq!(back.bits(), 8);
        assert_eq!(back.wp.shape(), &[NB, 4]);
        assert_eq!(back.mask.data(), packed_mask(8).data());
    }

    #[test]
    fn take_bitrep_moves_without_residue() {
        let mut s = ModelState::new();
        let w = Tensor::new(vec![3], vec![0.5, -0.25, 1.0]).unwrap();
        s.install_bitrep("conv1", crate::quant::to_bitplanes(&w, 4).unwrap());
        let rep = s.take_bitrep("conv1").unwrap();
        assert_eq!(rep.bits(), 4);
        // planes/mask are gone from the map, only the scale scalar remains
        assert!(!s.contains("wp:conv1"));
        assert!(!s.contains("wn:conv1"));
        assert!(!s.contains("mask:conv1"));
        assert!(s.contains("scale:conv1"));
        s.install_bitrep("conv1", rep);
        assert!(s.contains("wp:conv1"));
        // missing layers fail cleanly
        assert!(s.take_bitrep("nope").is_err());
    }

    #[test]
    fn zero_plane_momenta_clears_only_that_layer() {
        let mut s = ModelState::new();
        s.insert("m:wp:c1".into(), Tensor::full(&[2], 3.0));
        s.insert("m:wn:c1".into(), Tensor::full(&[2], 4.0));
        s.insert("m:wp:c2".into(), Tensor::full(&[2], 5.0));
        s.zero_plane_momenta("c1");
        assert!(s.get("m:wp:c1").unwrap().data().iter().all(|&v| v == 0.0));
        assert!(s.get("m:wn:c1").unwrap().data().iter().all(|&v| v == 0.0));
        assert!(s.get("m:wp:c2").unwrap().data().iter().all(|&v| v == 5.0));
        s.zero_plane_momenta("absent"); // no-op, not an error
    }

    #[test]
    fn ensure_momenta_idempotent() {
        let mut s = ModelState::new();
        let slots = vec![("m:w:x".to_string(), vec![3usize])];
        s.ensure_momenta(&slots);
        s.get_mut("m:w:x").unwrap().data_mut()[0] = 5.0;
        s.ensure_momenta(&slots); // must not reset existing buffer
        assert_eq!(s.get("m:w:x").unwrap().data()[0], 5.0);
    }
}
