//! Checkpointing: ModelState ⇄ a small self-describing binary format.
//!
//! Format (little-endian):
//!   magic "BSQCKPT1" | u32 entry count | entries…
//!   entry: u32 key len | key utf8 | u32 ndim | u64 dims… | f32 data…
//!
//! Plus a JSON sidecar (`.meta.json`) carrying run metadata (model name,
//! phase, epoch, scheme) for human inspection.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::model::state::ModelState;
use crate::tensor::Tensor;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"BSQCKPT1";

/// Per-entry element cap (2^31 ≈ 8 GiB of f32): a corrupt header must fail
/// with a clear error, not an absurd allocation.
const MAX_ELEMS: usize = 1 << 31;

pub fn save(state: &ModelState, path: &Path, meta: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
    let mut w = BufWriter::new(f);
    w.write_all(MAGIC)?;
    w.write_all(&(state.len() as u32).to_le_bytes())?;
    for (key, t) in state.iter() {
        w.write_all(&(key.len() as u32).to_le_bytes())?;
        w.write_all(key.as_bytes())?;
        w.write_all(&(t.shape().len() as u32).to_le_bytes())?;
        for &d in t.shape() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        let bytes = unsafe {
            std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len() * 4)
        };
        w.write_all(bytes)?;
    }
    w.flush()?;
    std::fs::write(path.with_extension("meta.json"), meta.to_string_pretty())?;
    Ok(())
}

pub fn load(path: &Path) -> Result<ModelState> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?} is not a BSQ checkpoint");
    }
    let count = read_u32(&mut r)? as usize;
    let mut state = ModelState::new();
    for _ in 0..count {
        let klen = read_u32(&mut r)? as usize;
        if klen > 1 << 16 {
            bail!("corrupt checkpoint: key length {klen}");
        }
        let mut kbuf = vec![0u8; klen];
        r.read_exact(&mut kbuf)?;
        let key = String::from_utf8(kbuf)?;
        let ndim = read_u32(&mut r)? as usize;
        if ndim > 16 {
            bail!("corrupt checkpoint: ndim {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            shape.push(u64::from_le_bytes(b) as usize);
        }
        // Overflow-checked element count: huge dims must not wrap into a
        // small (mis-sized) allocation that then misreads the stream.
        let n = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .filter(|&n| n <= MAX_ELEMS)
            .ok_or_else(|| {
                anyhow::anyhow!("corrupt checkpoint: entry {key:?} claims shape {shape:?}")
            })?;
        let mut data = vec![0f32; n];
        let bytes = unsafe {
            std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, n * 4)
        };
        r.read_exact(bytes)?;
        state.insert(key, Tensor::new(shape, data)?);
    }
    // A checkpoint is exactly its declared entries: trailing bytes mean a
    // corrupt entry count (or concatenated files) and used to load silently.
    let mut probe = [0u8; 1];
    match r.read(&mut probe)? {
        0 => Ok(state),
        _ => bail!("corrupt checkpoint: trailing bytes after {count} entries"),
    }
}

pub fn load_meta(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path.with_extension("meta.json"))?;
    crate::util::json::parse(&text)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn roundtrip() {
        let mut rng = Pcg32::seeded(0);
        let mut s = ModelState::new();
        s.insert("w:conv1".into(), Tensor::randn(&[3, 3, 2, 4], 0.5, &mut rng));
        s.insert("scale:conv1".into(), Tensor::scalar(0.7));
        s.insert("mask:conv1".into(), Tensor::full(&[9], 1.0));
        let dir = std::env::temp_dir().join(format!("bsq_ckpt_{}", std::process::id()));
        let path = dir.join("test.ckpt");
        let meta = Json::obj(vec![("model", Json::str("tinynet")), ("epoch", Json::num(3.0))]);
        save(&s, &path, &meta).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded.get("w:conv1").unwrap(), s.get("w:conv1").unwrap());
        assert_eq!(loaded.get("scale:conv1").unwrap().item().unwrap(), 0.7);
        let m = load_meta(&path).unwrap();
        assert_eq!(m.req("epoch").unwrap().as_usize().unwrap(), 3);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_non_checkpoint() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("bsq_not_ckpt_{}", std::process::id()));
        std::fs::write(&path, b"garbage!").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_trailing_bytes() {
        let mut s = ModelState::new();
        s.insert("w".into(), Tensor::scalar(1.0));
        let dir = std::env::temp_dir().join(format!("bsq_ckpt_trail_{}", std::process::id()));
        let path = dir.join("t.ckpt");
        save(&s, &path, &Json::obj(vec![])).unwrap();
        assert!(load(&path).is_ok());
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.push(0);
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_absurd_entry_shapes() {
        // magic | count 1 | key "w" | ndim 2 | dims [u64::MAX, u64::MAX]
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.push(b'w');
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        let path = std::env::temp_dir().join(format!("bsq_ckpt_huge_{}", std::process::id()));
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("corrupt checkpoint"), "{err}");
        std::fs::remove_file(path).ok();
    }
}
