//! Checkpointing: ModelState ⇄ a crash-safe self-describing binary format.
//!
//! Format v2 (little-endian):
//!   magic "BSQCKPT2"
//!   u32 entry count | u32 CRC32(count bytes)
//!   entry: u32 key len | key utf8 | u32 ndim | u64 dims… | f32 data…
//!          | u32 CRC32(every preceding byte of this entry)
//!
//! Every byte after the magic sits under a CRC32 (util::crc32), so a torn
//! write — truncation or bit-rot anywhere — fails loudly on load instead of
//! materializing garbage weights. `tests/chaos.rs` proves this exhaustively
//! by truncating at every length and flipping every bit of a saved file.
//!
//! Durability discipline: [`save`] writes a temp sibling, fsyncs it, then
//! atomically renames over the destination (and best-effort fsyncs the
//! directory), so the destination path only ever names a fully-written
//! file. The JSON sidecar (`.meta.json`) commits the same way, *before*
//! the binary — a crash between the two leaves a stale-meta/old-ckpt pair,
//! never a new-ckpt/missing-meta pair, and [`GenStore::latest_good`] only
//! trusts generations where both halves validate.
//!
//! Fault hooks: [`faults::CKPT_WRITE`] (`ioerr` → save fails with the old
//! file untouched) and [`faults::CKPT_COMMIT`] (`truncate`/`bitflip`
//! corrupt the fsynced temp file right before the rename — the torn write
//! the rename discipline cannot catch and the CRCs must).

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::faults::{self, FaultKind};
use crate::model::state::ModelState;
use crate::tensor::Tensor;
use crate::util::crc32::Crc32;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"BSQCKPT2";
const MAGIC_V1: &[u8; 8] = b"BSQCKPT1";

/// Per-entry element cap (2^31 ≈ 8 GiB of f32): a corrupt header must fail
/// with a clear error, not an absurd allocation.
const MAX_ELEMS: usize = 1 << 31;

/// Temp sibling in the same directory (rename must not cross filesystems).
fn tmp_sibling(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Write `bytes`, folding them into `crc`.
fn put<W: Write>(w: &mut W, crc: &mut Crc32, bytes: &[u8]) -> std::io::Result<()> {
    w.write_all(bytes)?;
    crc.update(bytes);
    Ok(())
}

/// fsync-then-rename commit of `bytes` to `path`. Shared with the model
/// store (`store/`), whose manifest and object files need the same
/// crash-safety as checkpoints themselves.
pub(crate) fn commit_bytes(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = tmp_sibling(path);
    {
        let mut f = std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
        f.write_all(bytes)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} → {path:?}"))?;
    Ok(())
}

fn fsync_dir(path: &Path) {
    if let Some(parent) = path.parent() {
        if let Ok(d) = std::fs::File::open(parent) {
            let _ = d.sync_all();
        }
    }
}

pub fn save(state: &ModelState, path: &Path, meta: &Json) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    if faults::take(faults::CKPT_WRITE, 0) == Some(FaultKind::IoError) {
        bail!("injected I/O error writing checkpoint {path:?}");
    }
    let tmp = tmp_sibling(path);
    {
        let f = std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        let mut hcrc = Crc32::new();
        put(&mut w, &mut hcrc, &(state.len() as u32).to_le_bytes())?;
        w.write_all(&hcrc.finalize().to_le_bytes())?;
        for (key, t) in state.iter() {
            let mut crc = Crc32::new();
            put(&mut w, &mut crc, &(key.len() as u32).to_le_bytes())?;
            put(&mut w, &mut crc, key.as_bytes())?;
            put(&mut w, &mut crc, &(t.shape().len() as u32).to_le_bytes())?;
            for &d in t.shape() {
                put(&mut w, &mut crc, &(d as u64).to_le_bytes())?;
            }
            let bytes = unsafe {
                std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.data().len() * 4)
            };
            put(&mut w, &mut crc, bytes)?;
            w.write_all(&crc.finalize().to_le_bytes())?;
        }
        let f = w.into_inner().map_err(|e| anyhow!("flushing {tmp:?}: {e}"))?;
        f.sync_all()?;
    }
    // Meta commits before the binary: latest_good requires both halves, so
    // a crash between the renames can only hide this generation, never
    // pair the new binary with a missing/old sidecar.
    commit_bytes(&path.with_extension("meta.json"), meta.to_string_pretty().as_bytes())?;
    match faults::take(faults::CKPT_COMMIT, 0) {
        Some(FaultKind::Truncate(n)) => {
            let len = std::fs::metadata(&tmp)?.len();
            let f = std::fs::OpenOptions::new().write(true).open(&tmp)?;
            f.set_len(len.saturating_sub(n))?;
        }
        Some(FaultKind::BitFlip(off)) => {
            let mut bytes = std::fs::read(&tmp)?;
            if !bytes.is_empty() {
                let i = (off % bytes.len() as u64) as usize;
                bytes[i] ^= 1;
                std::fs::write(&tmp, &bytes)?;
            }
        }
        _ => {}
    }
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} → {path:?}"))?;
    fsync_dir(path);
    Ok(())
}

pub fn load(path: &Path) -> Result<ModelState> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic == MAGIC_V1 {
        bail!("{path:?} is a v1 (pre-CRC) checkpoint; regenerate it with this build");
    }
    if &magic != MAGIC {
        bail!("{path:?} is not a BSQ checkpoint");
    }
    let mut hcrc = Crc32::new();
    let count = get_u32(&mut r, &mut hcrc)? as usize;
    if read_u32(&mut r)? != hcrc.finalize() {
        bail!("corrupt checkpoint: entry-count CRC mismatch in {path:?}");
    }
    let mut state = ModelState::new();
    for _ in 0..count {
        let mut crc = Crc32::new();
        let klen = get_u32(&mut r, &mut crc)? as usize;
        if klen > 1 << 16 {
            bail!("corrupt checkpoint: key length {klen}");
        }
        let mut kbuf = vec![0u8; klen];
        r.read_exact(&mut kbuf)?;
        crc.update(&kbuf);
        let key = String::from_utf8(kbuf)?;
        let ndim = get_u32(&mut r, &mut crc)? as usize;
        if ndim > 16 {
            bail!("corrupt checkpoint: ndim {ndim}");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            crc.update(&b);
            shape.push(u64::from_le_bytes(b) as usize);
        }
        // Overflow-checked element count: huge dims must not wrap into a
        // small (mis-sized) allocation that then misreads the stream.
        let n = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .filter(|&n| n <= MAX_ELEMS)
            .ok_or_else(|| anyhow!("corrupt checkpoint: entry {key:?} claims shape {shape:?}"))?;
        let mut data = vec![0f32; n];
        let bytes =
            unsafe { std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, n * 4) };
        r.read_exact(bytes)?;
        crc.update(bytes);
        if read_u32(&mut r)? != crc.finalize() {
            bail!("corrupt checkpoint: entry {key:?} CRC mismatch in {path:?}");
        }
        state.insert(key, Tensor::new(shape, data)?);
    }
    // A checkpoint is exactly its declared entries: trailing bytes mean a
    // corrupt entry count (or concatenated files) and used to load silently.
    let mut probe = [0u8; 1];
    match r.read(&mut probe)? {
        0 => Ok(state),
        _ => bail!("corrupt checkpoint: trailing bytes after {count} entries"),
    }
}

pub fn load_meta(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path.with_extension("meta.json"))?;
    crate::util::json::parse(&text)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// `read_u32` that also folds the bytes into a running CRC.
fn get_u32<R: Read>(r: &mut R, crc: &mut Crc32) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    crc.update(&b);
    Ok(u32::from_le_bytes(b))
}

/// N-generation checkpoint retention with fallback to the newest
/// generation that still validates. Layout: `<dir>/gen-NNNNNN.ckpt` plus
/// the usual `.meta.json` sidecar per generation.
pub struct GenStore {
    dir: PathBuf,
    keep: usize,
}

impl GenStore {
    pub fn new(dir: impl Into<PathBuf>, keep: usize) -> GenStore {
        GenStore { dir: dir.into(), keep: keep.max(1) }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn path(&self, generation: u64) -> PathBuf {
        self.dir.join(format!("gen-{generation:06}.ckpt"))
    }

    /// Generation numbers present on disk, ascending (validity not checked).
    pub fn generations(&self) -> Vec<u64> {
        let Ok(entries) = std::fs::read_dir(&self.dir) else { return Vec::new() };
        let mut gens: Vec<u64> = entries
            .flatten()
            .filter_map(|e| {
                let name = e.file_name();
                let name = name.to_str()?;
                name.strip_prefix("gen-")?.strip_suffix(".ckpt")?.parse().ok()
            })
            .collect();
        gens.sort_unstable();
        gens
    }

    /// Save `generation`, then prune down to the newest `keep` generations.
    pub fn save_generation(&self, generation: u64, state: &ModelState, meta: &Json) -> Result<()> {
        save(state, &self.path(generation), meta)
            .with_context(|| format!("saving snapshot generation {generation}"))?;
        let gens = self.generations();
        if gens.len() > self.keep {
            for &g in &gens[..gens.len() - self.keep] {
                let p = self.path(g);
                let _ = std::fs::remove_file(&p);
                let _ = std::fs::remove_file(p.with_extension("meta.json"));
            }
        }
        Ok(())
    }

    /// Newest generation whose binary *and* meta sidecar both validate;
    /// corrupt generations are logged and skipped — the fallback path that
    /// makes a torn final write survivable.
    pub fn latest_good(&self) -> Option<(u64, ModelState, Json)> {
        for &g in self.generations().iter().rev() {
            let p = self.path(g);
            match load(&p).and_then(|s| Ok((s, load_meta(&p)?))) {
                Ok((state, meta)) => return Some((g, state, meta)),
                Err(e) => {
                    log::warn!("snapshot generation {g} unusable ({e:#}); falling back");
                }
            }
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn scratch(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("bsq_ckpt_{tag}_{}", std::process::id()))
    }

    fn sample_state(seed: u64) -> ModelState {
        let mut rng = Pcg32::seeded(seed);
        let mut s = ModelState::new();
        s.insert("w:conv1".into(), Tensor::randn(&[3, 3, 2, 4], 0.5, &mut rng));
        s.insert("scale:conv1".into(), Tensor::scalar(0.7));
        s.insert("mask:conv1".into(), Tensor::full(&[9], 1.0));
        s
    }

    #[test]
    fn roundtrip() {
        let s = sample_state(0);
        let dir = scratch("rt");
        let path = dir.join("test.ckpt");
        let meta = Json::obj(vec![("model", Json::str("tinynet")), ("epoch", Json::num(3.0))]);
        save(&s, &path, &meta).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), 3);
        assert_eq!(loaded.get("w:conv1").unwrap(), s.get("w:conv1").unwrap());
        assert_eq!(loaded.get("scale:conv1").unwrap().item().unwrap(), 0.7);
        let m = load_meta(&path).unwrap();
        assert_eq!(m.req("epoch").unwrap().as_usize().unwrap(), 3);
        // no temp siblings left behind
        assert!(!tmp_sibling(&path).exists());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_non_checkpoint() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("bsq_not_ckpt_{}", std::process::id()));
        std::fs::write(&path, b"garbage!").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_v1_checkpoints() {
        let path = std::env::temp_dir().join(format!("bsq_ckpt_v1_{}", std::process::id()));
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC_V1);
        bytes.extend_from_slice(&0u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("v1"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_trailing_bytes() {
        let mut s = ModelState::new();
        s.insert("w".into(), Tensor::scalar(1.0));
        let dir = scratch("trail");
        let path = dir.join("t.ckpt");
        save(&s, &path, &Json::obj(vec![])).unwrap();
        assert!(load(&path).is_ok());
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.push(0);
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("trailing"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_absurd_entry_shapes() {
        // magic | count 1 + CRC | key "w" | ndim 2 | dims [u64::MAX, u64::MAX]
        let mut bytes: Vec<u8> = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&crate::util::crc32::crc32(&1u32.to_le_bytes()).to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.push(b'w');
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        let path = std::env::temp_dir().join(format!("bsq_ckpt_huge_{}", std::process::id()));
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("corrupt checkpoint"), "{err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn detects_payload_corruption() {
        let s = sample_state(1);
        let dir = scratch("crc");
        let path = dir.join("t.ckpt");
        save(&s, &path, &Json::obj(vec![])).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // flip one bit deep in the tensor-data region
        let i = bytes.len() - 24;
        bytes[i] ^= 0x10;
        std::fs::write(&path, &bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("CRC mismatch"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn gen_store_prunes_to_keep_and_falls_back_over_corruption() {
        let dir = scratch("gens");
        let store = GenStore::new(&dir, 3);
        for g in 0..5u64 {
            let meta = Json::obj(vec![("gen", Json::num(g as f64))]);
            store.save_generation(g, &sample_state(g), &meta).unwrap();
        }
        assert_eq!(store.generations(), vec![2, 3, 4]);

        let (g, state, meta) = store.latest_good().unwrap();
        assert_eq!(g, 4);
        assert_eq!(meta.req("gen").unwrap().as_usize().unwrap(), 4);
        assert_eq!(state.get("w:conv1").unwrap(), sample_state(4).get("w:conv1").unwrap());

        // corrupt the newest binary → falls back one generation
        let mut bytes = std::fs::read(store.path(4)).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 1;
        std::fs::write(store.path(4), &bytes).unwrap();
        let (g, state, _) = store.latest_good().unwrap();
        assert_eq!(g, 3);
        assert_eq!(state.get("w:conv1").unwrap(), sample_state(3).get("w:conv1").unwrap());

        // corrupt that generation's meta sidecar → falls back again
        std::fs::write(store.path(3).with_extension("meta.json"), b"{ torn").unwrap();
        let (g, _, _) = store.latest_good().unwrap();
        assert_eq!(g, 2);

        std::fs::remove_dir_all(dir).ok();
    }
}
