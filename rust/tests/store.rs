//! Integration tests for the content-addressed model store (DESIGN.md §14):
//! digest round-trips, manifest pin/resolve (missing hash is a hard error),
//! byte-budgeted LRU eviction, gc retention (pinned and recently-deployed
//! objects survive, orphans don't), and GenStore→store publication —
//! including that publication never disturbs the snapshot store's own
//! `latest_good` fallback semantics.

use std::path::PathBuf;

use bsq::coordinator::StorePublisher;
use bsq::model::checkpoint::{self, GenStore};
use bsq::model::ModelState;
use bsq::runtime::Engine;
use bsq::serve;
use bsq::store::{digest_file, digest_hex, ByteLru, DeployPin, Manifest, ModelStore};
use bsq::util::Json;

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bsq_store_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn quantized_ckpt(engine: &Engine, dir: &std::path::Path, seed: u64) -> PathBuf {
    let path = dir.join(format!("q_s{seed}.ckpt"));
    serve::synthesize_quantized_checkpoint(engine, "tinynet", 6, seed, &path).unwrap();
    path
}

fn pin(model: &str, hash: &str) -> DeployPin {
    DeployPin {
        model: model.to_string(),
        weights_hash: hash.to_string(),
        precision_fp: "0123456789abcdef".into(),
        plan_fp: "fedcba9876543210".into(),
        act_bits: 4,
        act_first_last: 8,
        source: "test".into(),
    }
}

// ---------------------------------------------------------------- digest

#[test]
fn content_hash_roundtrip_same_bytes_same_key() {
    let dir = scratch("hash_rt");
    let a = dir.join("a.bin");
    let b = dir.join("b.bin");
    let payload: Vec<u8> = (0..4096u32).map(|i| (i * 31 % 251) as u8).collect();
    std::fs::write(&a, &payload).unwrap();
    std::fs::write(&b, &payload).unwrap();

    // identity is the bytes, not the path
    assert_eq!(digest_file(&a).unwrap(), digest_file(&b).unwrap());
    assert_eq!(digest_file(&a).unwrap(), digest_hex(&payload));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn one_bit_flip_is_a_new_key() {
    let payload: Vec<u8> = (0..512u32).map(|i| (i % 256) as u8).collect();
    let base = digest_hex(&payload);
    // every single-bit corruption lands on a different digest
    for byte in [0usize, 1, 255, 511] {
        for bit in 0..8 {
            let mut flipped = payload.clone();
            flipped[byte] ^= 1 << bit;
            assert_ne!(digest_hex(&flipped), base, "byte {byte} bit {bit} collided");
        }
    }
}

// -------------------------------------------------------------- manifest

#[test]
fn manifest_pin_resolve_and_missing_hash_hard_error() {
    let dir = scratch("manifest");
    let path = dir.join("manifest.json");

    let mut m = Manifest::new();
    let h1 = digest_hex(b"weights v1");
    assert!(m.pin(pin("tinynet", &h1)).unwrap().is_none());
    m.save(&path).unwrap();

    // load → resolve round-trips the pin exactly
    let m2 = Manifest::load(&path).unwrap();
    assert_eq!(m2.resolve("tinynet").unwrap().weights_hash, h1);
    assert_eq!(m2.resolve("tinynet").unwrap().source, "test");

    // unknown model is a hard error naming what *is* pinned
    let err = m2.resolve("resnet20").unwrap_err().to_string();
    assert!(err.contains("resnet20"), "{err}");

    // a pin whose hash is not a digest is rejected outright
    let mut bad = Manifest::new();
    let err = bad.pin(pin("tinynet", "not-a-digest")).unwrap_err().to_string();
    assert!(err.contains("weights_hash"), "{err}");

    // re-pinning the same model replaces (returns the old pin)
    let mut m3 = Manifest::load(&path).unwrap();
    let h2 = digest_hex(b"weights v2");
    let replaced = m3.pin(pin("tinynet", &h2)).unwrap().unwrap();
    assert_eq!(replaced.weights_hash, h1);
    assert_eq!(m3.resolve("tinynet").unwrap().weights_hash, h2);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn store_refuses_pins_to_absent_objects() {
    let dir = scratch("absent");
    let mut store = ModelStore::open(dir.join("store")).unwrap();
    let err = store.pin_deploy(pin("tinynet", &digest_hex(b"never ingested"))).unwrap_err();
    assert!(err.to_string().contains("not in store"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}

// ------------------------------------------------------------------- lru

#[test]
fn lru_evicts_cold_entries_within_a_byte_budget() {
    // 100-byte budget, 40-byte entries: the third insert evicts the
    // least-recently-used, and touching an entry protects it.
    let mut lru: ByteLru<&'static str> = ByteLru::new(100);
    lru.insert("a", std::sync::Arc::new("A"), 40);
    lru.insert("b", std::sync::Arc::new("B"), 40);
    assert!(lru.get("a").is_some()); // a is now hotter than b
    lru.insert("c", std::sync::Arc::new("C"), 40);
    assert!(!lru.contains("b"), "cold entry should have been evicted");
    assert!(lru.contains("a") && lru.contains("c"));
    assert_eq!(lru.evictions(), 1);
    assert!(lru.resident_bytes() <= 100);
}

// -------------------------------------------------- store ⇄ checkpoints

#[test]
fn put_checkpoint_is_idempotent_and_keyed_by_content() {
    let engine = Engine::native();
    let dir = scratch("put");
    let ckpt = quantized_ckpt(&engine, &dir, 7);
    let store = ModelStore::open(dir.join("store")).unwrap();

    let k1 = store.put_checkpoint(&ckpt).unwrap();
    let k2 = store.put_checkpoint(&ckpt).unwrap();
    assert_eq!(k1, k2, "re-adding identical bytes must land on the same object");
    assert_eq!(store.objects(), vec![k1.clone()]);
    assert!(store.object_path(&k1).exists());

    // the stored object is byte-identical to the source checkpoint
    assert_eq!(digest_file(&store.object_path(&k1)).unwrap(), k1);

    // a different checkpoint is a different object; both coexist
    let other = quantized_ckpt(&engine, &dir, 8);
    let k3 = store.put_checkpoint(&other).unwrap();
    assert_ne!(k1, k3);
    assert_eq!(store.objects().len(), 2);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn gc_spares_pinned_and_recent_objects_and_reclaims_the_rest() {
    let engine = Engine::native();
    let dir = scratch("gc");
    let ckpts: Vec<_> = (0..4).map(|s| quantized_ckpt(&engine, &dir, 20 + s)).collect();
    let mut store = ModelStore::open(dir.join("store")).unwrap();

    // deploy history: k0 (seq 1) → k1 (seq 2) → k2 (seq 3, current pin);
    // k3 is ingested but never pinned — an orphan at any horizon.
    let keys: Vec<String> = ckpts.iter().map(|c| store.put_checkpoint(c).unwrap()).collect();
    for key in &keys[..3] {
        store.pin_deploy(pin("tinynet", key)).unwrap();
    }
    assert_eq!(store.objects().len(), 4);

    // dry run deletes nothing, but reports what a real pass would take
    let preview = store.gc(1, true).unwrap();
    assert!(preview.dry_run);
    assert_eq!(preview.deleted.len(), 2); // k0 (too old) + k3 (orphan)
    assert!(preview.bytes_freed > 0);
    assert_eq!(store.objects().len(), 4, "dry run must not delete");

    // keep-deploys 1: survivors are the current pin and the last deploy
    let report = store.gc(1, false).unwrap();
    let mut gone = report.deleted.clone();
    gone.sort();
    let mut expect = vec![keys[0].clone(), keys[3].clone()];
    expect.sort();
    assert_eq!(gone, expect);
    assert_eq!(report.kept, 2);
    assert_eq!(report.bytes_freed, preview.bytes_freed);
    assert!(!store.object_path(&keys[0]).exists());
    assert!(store.object_path(&keys[1]).exists(), "recently-deployed object must survive");
    assert!(store.object_path(&keys[2]).exists(), "pinned object must survive");

    // the store still resolves and serves after the gc
    let (live, obj) = store.resolve("tinynet").unwrap();
    assert_eq!(live.weights_hash, keys[2]);
    assert_eq!(digest_file(&obj).unwrap(), keys[2]);

    // gc is idempotent once the garbage is gone
    let again = store.gc(1, false).unwrap();
    assert!(again.deleted.is_empty());
    assert_eq!(again.kept, 2);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn genstore_publication_pins_the_exact_generation() {
    let engine = Engine::native();
    let dir = scratch("publish");
    let ckpt = quantized_ckpt(&engine, &dir, 3);

    // put the quantized state through a GenStore, like the trainer does
    let state = checkpoint::load(&ckpt).unwrap();
    let gens = GenStore::new(dir.join("snap"), 3);
    let meta = Json::obj(vec![("gen", Json::num(0.0))]);
    gens.save_generation(0, &state, &meta).unwrap();

    let store_root = dir.join("store");
    let publisher = StorePublisher::new(&engine, &store_root, "tinynet", 4, 8);
    let digest = publisher.publish(&gens.path(0), 0).unwrap();

    // the pin records the exact (weights, precision, plan) triple + origin
    let store = ModelStore::open(&store_root).unwrap();
    let (pin, obj) = store.resolve("tinynet").unwrap();
    assert_eq!(pin.weights_hash, digest);
    assert_eq!(pin.source, "gen-000000");
    assert_eq!(pin.precision_fp.len(), 16);
    assert_eq!(pin.plan_fp.len(), 16);
    assert_eq!(digest_file(&obj).unwrap(), digest);
    // the meta sidecar rode along into the store
    assert!(obj.with_extension("meta.json").exists());

    // publication must not disturb the snapshot store's own semantics:
    // latest_good still resolves, to the same generation, bit-identically
    let (g, resumed, _) = gens.latest_good().expect("snapshot store intact");
    assert_eq!(g, 0);
    for name in resumed.keys() {
        assert_eq!(resumed.get(name).unwrap(), state.get(name).unwrap(), "{name}");
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn publishing_a_float_checkpoint_is_the_not_servable_error() {
    let engine = Engine::native();
    let dir = scratch("fp_pub");
    let man = engine.manifest("tinynet").unwrap();
    let state = ModelState::init_fp(&man, 0);
    let gens = GenStore::new(dir.join("snap"), 3);
    gens.save_generation(0, &state, &Json::obj(vec![])).unwrap();

    let publisher = StorePublisher::new(&engine, dir.join("store"), "tinynet", 4, 8);
    let err = format!("{:#}", publisher.publish(&gens.path(0), 0).unwrap_err());
    // the trainer's lenient skip keys off this phrase — keep it stable
    assert!(err.contains("bit-representation"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}
