//! Chaos suite (DESIGN.md §12): seeded fault schedules against training,
//! checkpointing, and serving, asserting the recovery invariants —
//!
//! * a training run killed in any phase resumes from its last snapshot to a
//!   **bit-identical** trajectory (history, scheme, accuracies);
//! * snapshotting itself is a pure observer (on vs off: same bits);
//! * a fault in the overlapped requant rebuild or at its install barrier
//!   (DESIGN.md §16) dies cleanly pre-install and resumes bit-identically
//!   — in either mode, regardless of which mode crashed;
//! * a checkpoint torn at *any* length or flipped in *any* bit fails loudly
//!   on load, and generation retention falls back over corruption;
//! * the serving pool answers every request exactly once under injected
//!   worker panics, converts expired requests into timeout responses, and
//!   sheds load with retry-after instead of blocking — and never hangs.
//!
//! Every training/serving section runs under a `faults::inject` guard
//! (empty schedule = pure counting), which serializes chaos tests through
//! the process-global plane — concurrent tests must not perturb each
//! other's occurrence counters. Each guarded section also runs under a
//! [`with_deadline`] watchdog so a recovery bug surfaces as a failed test,
//! not a hung CI job.

use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::OnceLock;
use std::time::Duration;

use bsq::coordinator::{run_bsq, BsqConfig, BsqOutcome, History, SnapshotCfg};
use bsq::faults::{self, Schedule};
use bsq::model::checkpoint::{self, GenStore};
use bsq::model::ModelState;
use bsq::runtime::Engine;
use bsq::serve::{
    self, run_closed_loop, Admission, BatchPolicy, PoolConfig, ServableModel, ServeStatus,
};
use bsq::tensor::Tensor;
use bsq::util::{Json, Pcg32};

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bsq_chaos_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run `f` on a watchdog thread: a hang past `secs` fails the test instead
/// of stalling the harness; a panic inside `f` is re-raised with its
/// original message.
fn with_deadline<T: Send + 'static>(
    secs: u64,
    what: &str,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = mpsc::channel();
    let h = std::thread::Builder::new()
        .name(format!("chaos-{what}"))
        .spawn(move || {
            let _ = tx.send(f());
        })
        .unwrap();
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(v) => {
            let _ = h.join();
            v
        }
        Err(mpsc::RecvTimeoutError::Disconnected) => match h.join() {
            Err(p) => std::panic::resume_unwind(p),
            Ok(()) => panic!("{what}: worker exited without a result"),
        },
        Err(mpsc::RecvTimeoutError::Timeout) => {
            panic!("{what} exceeded its {secs}s hang deadline")
        }
    }
}

// -- training: kill → resume bit-identity -------------------------------------

/// Tiny but phase-complete pipeline: tinynet batch 16, train 48 → exactly
/// 3 steps/epoch, so shard worker 0's occurrence counter maps to the global
/// train-step index: pretrain steps 0–5, bsq 6–11, finetune 12–14.
fn tiny_cfg() -> BsqConfig {
    let mut cfg = BsqConfig::for_model("tinynet");
    cfg.pretrain_epochs = 2;
    cfg.bsq_epochs = 2;
    cfg.finetune_epochs = 1;
    cfg.requant_interval = 1;
    cfg.train_size = 48;
    cfg.test_size = 32;
    cfg.eval_batches = 2;
    cfg.alpha_ref_steps = 0.0;
    cfg.cache_pretrained = false; // the on-disk pretrain cache would couple trials
    cfg
}

fn run_tiny(cfg: &BsqConfig) -> anyhow::Result<BsqOutcome> {
    let cfg = cfg.clone();
    with_deadline(300, "run_bsq", move || run_bsq(&Engine::native().with_shards(2), &cfg))
}

/// The bitwise fingerprint of a training trajectory (everything except the
/// wall-clock `seconds` field).
fn traj(h: &History) -> Vec<(String, usize, u32, u32, u32, u32, u32, Option<u32>, u64, u64)> {
    h.records
        .iter()
        .map(|r| {
            (
                r.phase.clone(),
                r.epoch,
                r.lr.to_bits(),
                r.loss.to_bits(),
                r.ce.to_bits(),
                r.acc.to_bits(),
                r.bgl.to_bits(),
                r.eval_acc.map(f32::to_bits),
                r.bits_per_param.to_bits(),
                r.compression.to_bits(),
            )
        })
        .collect()
}

fn assert_same_outcome(a: &BsqOutcome, b: &BsqOutcome, label: &str) {
    assert_eq!(traj(&a.history), traj(&b.history), "{label}: trajectory diverged");
    assert_eq!(a.scheme, b.scheme, "{label}: scheme diverged");
    assert_eq!(
        a.acc_before_ft.to_bits(),
        b.acc_before_ft.to_bits(),
        "{label}: acc_before_ft diverged"
    );
    assert_eq!(
        a.acc_after_ft.to_bits(),
        b.acc_after_ft.to_bits(),
        "{label}: acc_after_ft diverged"
    );
    assert_eq!(
        a.bits_per_param.to_bits(),
        b.bits_per_param.to_bits(),
        "{label}: bits_per_param diverged"
    );
    assert_eq!(a.compression.to_bits(), b.compression.to_bits(), "{label}: compression diverged");
}

/// The uninterrupted reference run, computed once per process.
fn baseline() -> &'static BsqOutcome {
    static BASELINE: OnceLock<BsqOutcome> = OnceLock::new();
    BASELINE.get_or_init(|| {
        let _g = faults::inject(Schedule::default());
        run_tiny(&tiny_cfg()).expect("uninterrupted baseline run")
    })
}

#[test]
fn snapshotting_is_a_pure_observer() {
    let dir = scratch("observer");
    let mut cfg = tiny_cfg();
    cfg.snapshot = Some(SnapshotCfg::new(&dir));
    let out = {
        let _g = faults::inject(Schedule::default());
        run_tiny(&cfg).unwrap()
    };
    assert_same_outcome(baseline(), &out, "snapshot on vs off");
    // every epoch snapshotted, pruned to the newest `keep`
    let store = GenStore::new(&dir, 3);
    assert_eq!(store.generations(), vec![2, 3, 4]);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn killed_training_resumes_bit_identically_from_any_phase() {
    // Worker 0's occurrence = global train-step index (3 steps/epoch):
    // 4 → pretrain epoch 1, 7 → bsq epoch 0, 10 → bsq epoch 1,
    // 13 → finetune epoch 0. One kill per phase boundary class.
    for (occ, label) in
        [(4u64, "pretrain e1"), (7, "bsq e0"), (10, "bsq e1"), (13, "finetune e0")]
    {
        let dir = scratch(&format!("kill{occ}"));
        let mut cfg = tiny_cfg();
        cfg.snapshot = Some(SnapshotCfg::new(&dir));

        {
            let g = faults::inject(
                Schedule::parse(&format!("shard.worker#0@{occ}:panic")).unwrap(),
            );
            let err = run_tiny(&cfg).expect_err(label);
            assert!(
                format!("{err:#}").contains("injected fault"),
                "{label}: wrong failure: {err:#}"
            );
            assert_eq!(g.fired().len(), 1, "{label}: fault did not fire");
        }

        let resumed = {
            let _g = faults::inject(Schedule::default());
            let mut rcfg = cfg.clone();
            rcfg.resume = true;
            run_tiny(&rcfg).unwrap_or_else(|e| panic!("{label}: resume failed: {e:#}"))
        };
        assert_same_outcome(baseline(), &resumed, label);
        std::fs::remove_dir_all(dir).ok();
    }
}

#[test]
fn barrier_panic_poisons_nothing_fatal_and_resumes_bit_identically() {
    // shard.barrier is timing-dependent (occurrences per step depend on the
    // graph's exchange count), so calibrate `@nth` from a pure-counting
    // probe run instead of hardcoding it.
    let total = {
        let g = faults::inject(Schedule::default());
        run_tiny(&tiny_cfg()).unwrap();
        let t = faults::occurrences(faults::SHARD_BARRIER, 0);
        drop(g);
        t
    };
    assert!(total > 0, "tinynet training must cross lockstep barriers");
    let mid = total / 2; // lands mid-bsq: past the first snapshot, before the end

    let dir = scratch("barrier");
    let mut cfg = tiny_cfg();
    cfg.snapshot = Some(SnapshotCfg::new(&dir));
    {
        let _g =
            faults::inject(Schedule::parse(&format!("shard.barrier@{mid}:panic")).unwrap());
        let err = run_tiny(&cfg).expect_err("barrier kill");
        // The panic fires while the barrier mutex is held — the run must
        // report the injected root cause, not a PoisonError cascade.
        assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
    }
    let resumed = {
        let _g = faults::inject(Schedule::default());
        let mut rcfg = cfg.clone();
        rcfg.resume = true;
        run_tiny(&rcfg).unwrap()
    };
    assert_same_outcome(baseline(), &resumed, "barrier kill");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn resume_falls_back_over_corrupt_generations_bit_identically() {
    let dir = scratch("fallback");
    let mut cfg = tiny_cfg();
    cfg.snapshot = Some(SnapshotCfg::new(&dir));
    {
        let _g = faults::inject(Schedule::parse("shard.worker#0@13:panic").unwrap());
        run_tiny(&cfg).expect_err("finetune kill");
    }
    // On disk (keep 3): gen 1 (pretrain e1), gen 2 (bsq e0), gen 3 (bsq e1).
    // Tear the newest binary and the next one's meta sidecar: resume must
    // fall back two generations and still match the baseline bits.
    let g3 = dir.join("gen-000003.ckpt");
    let mut bytes = std::fs::read(&g3).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 1;
    std::fs::write(&g3, &bytes).unwrap();
    std::fs::write(dir.join("gen-000002.meta.json"), b"{ torn").unwrap();
    let (gen, _, _) = GenStore::new(&dir, 3).latest_good().unwrap();
    assert_eq!(gen, 1, "fallback must land on the pretrain-e1 generation");

    let resumed = {
        let _g = faults::inject(Schedule::default());
        let mut rcfg = cfg.clone();
        rcfg.resume = true;
        run_tiny(&rcfg).unwrap()
    };
    assert_same_outcome(baseline(), &resumed, "corrupt-generation fallback");
    std::fs::remove_dir_all(dir).ok();
}

// -- overlapped re-quantization faults (DESIGN.md §16) ------------------------

/// A worker panic during the overlapped rebuild must surface as a clean
/// error *before* any plane is installed or any bsq snapshot taken, and a
/// resume — in either mode, including the mode the run did NOT crash in —
/// replays to the baseline bits. `requant.worker#0` is keyed by chunk
/// index, so `@1` addresses the second requant boundary (bsq epoch 1)
/// regardless of how many worker chunks this host splits the layers into.
#[test]
fn requant_worker_kill_resumes_bit_identically_across_modes() {
    for (sync, label) in [(true, "killed sync, resumed overlapped"),
                          (false, "killed overlapped, resumed sync")] {
        let dir = scratch(if sync { "rq_sync" } else { "rq_overlap" });
        let mut cfg = tiny_cfg();
        cfg.sync_requant = sync;
        cfg.prefetch_depth = if sync { 0 } else { 2 };
        cfg.snapshot = Some(SnapshotCfg::new(&dir));

        {
            let g = faults::inject(Schedule::parse("requant.worker#0@1:panic").unwrap());
            let err = run_tiny(&cfg).expect_err(label);
            assert!(
                format!("{err:#}").contains("injected fault"),
                "{label}: wrong failure: {err:#}"
            );
            assert_eq!(g.fired().len(), 1, "{label}: fault did not fire");
        }

        // Resume in the OTHER mode: the knobs are outside the config
        // fingerprint precisely so an operator can fall back to
        // --sync-requant on a crashed overlapped run (and vice versa).
        let resumed = {
            let _g = faults::inject(Schedule::default());
            let mut rcfg = cfg.clone();
            rcfg.resume = true;
            rcfg.sync_requant = !sync;
            rcfg.prefetch_depth = if sync { 2 } else { 0 };
            run_tiny(&rcfg).unwrap_or_else(|e| panic!("{label}: resume failed: {e:#}"))
        };
        assert_same_outcome(baseline(), &resumed, label);
        std::fs::remove_dir_all(dir).ok();
    }
}

/// The install barrier is all-or-nothing: a fault at `requant.install`
/// kills the run with every live plane untouched (the next resume replays
/// the epoch and lands on the baseline bits, which it could not if some
/// layers had already swapped).
#[test]
fn requant_install_fault_is_all_or_nothing() {
    let dir = scratch("rq_install");
    let mut cfg = tiny_cfg();
    cfg.sync_requant = false;
    cfg.snapshot = Some(SnapshotCfg::new(&dir));
    {
        let g = faults::inject(Schedule::parse("requant.install@0:panic").unwrap());
        let err = run_tiny(&cfg).expect_err("install kill");
        assert!(format!("{err:#}").contains("injected fault"), "{err:#}");
        assert_eq!(g.fired().len(), 1, "install fault did not fire");
    }
    let resumed = {
        let _g = faults::inject(Schedule::default());
        let mut rcfg = cfg.clone();
        rcfg.resume = true;
        run_tiny(&rcfg).unwrap()
    };
    assert_same_outcome(baseline(), &resumed, "install kill");
    std::fs::remove_dir_all(dir).ok();
}

/// A slow rebuild worker must stall the install barrier, never be raced
/// past it: delaying chunk 0 through the whole eval window changes no
/// bits, only wall clock.
#[test]
fn slow_requant_worker_stalls_the_install_never_corrupts_it() {
    let out = {
        let g = faults::inject(
            Schedule::parse("requant.worker#0@0:delay=100; requant.worker#0@1:delay=100")
                .unwrap(),
        );
        let mut cfg = tiny_cfg();
        cfg.sync_requant = false;
        let out = run_tiny(&cfg).unwrap();
        assert_eq!(g.fired().len(), 2, "both delays must fire");
        out
    };
    assert_same_outcome(baseline(), &out, "delayed worker");
}

// -- checkpoint torn-write properties -----------------------------------------

fn tiny_ckpt_state(seed: u64) -> ModelState {
    let mut rng = Pcg32::seeded(seed);
    let mut s = ModelState::new();
    s.insert("w:a".into(), Tensor::randn(&[2, 3], 0.5, &mut rng));
    s.insert("b".into(), Tensor::scalar(1.5));
    s.insert("mask".into(), Tensor::full(&[4], 1.0));
    s
}

#[test]
fn every_truncation_of_a_checkpoint_fails_loudly() {
    let dir = scratch("trunc");
    let path = dir.join("t.ckpt");
    checkpoint::save(&tiny_ckpt_state(3), &path, &Json::obj(vec![])).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    assert!(checkpoint::load(&path).is_ok());

    let torn = dir.join("torn.ckpt");
    for len in 0..bytes.len() {
        std::fs::write(&torn, &bytes[..len]).unwrap();
        assert!(
            checkpoint::load(&torn).is_err(),
            "a checkpoint truncated to {len}/{} bytes loaded silently",
            bytes.len()
        );
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn every_single_bit_flip_in_a_checkpoint_fails_loudly() {
    let dir = scratch("flip");
    let path = dir.join("t.ckpt");
    checkpoint::save(&tiny_ckpt_state(4), &path, &Json::obj(vec![])).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    let flipped = dir.join("flipped.ckpt");
    for i in 0..bytes.len() {
        for bit in 0..8 {
            let mut b = bytes.clone();
            b[i] ^= 1 << bit;
            std::fs::write(&flipped, &b).unwrap();
            assert!(
                checkpoint::load(&flipped).is_err(),
                "flipping byte {i} bit {bit} loaded silently"
            );
        }
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn injected_save_faults_never_corrupt_the_committed_file_silently() {
    let dir = scratch("savefault");
    let path = dir.join("t.ckpt");
    let meta = Json::obj(vec![("k", Json::str("v"))]);
    let first = tiny_ckpt_state(5);
    checkpoint::save(&first, &path, &meta).unwrap();

    // ckpt.write ioerr: the save fails before any byte lands; the previous
    // checkpoint (and its meta) stay fully readable.
    {
        let _g = faults::inject(Schedule::parse("ckpt.write@0:ioerr").unwrap());
        let err = checkpoint::save(&tiny_ckpt_state(6), &path, &meta).unwrap_err();
        assert!(format!("{err:#}").contains("injected"), "{err:#}");
    }
    let loaded = checkpoint::load(&path).unwrap();
    assert_eq!(loaded.get("w:a").unwrap(), first.get("w:a").unwrap());
    assert!(checkpoint::load_meta(&path).is_ok());

    // ckpt.commit truncate: the torn write lands past the rename discipline
    // — the CRCs must catch it on load.
    {
        let _g = faults::inject(Schedule::parse("ckpt.commit@0:truncate=7").unwrap());
        checkpoint::save(&tiny_ckpt_state(6), &path, &meta).unwrap();
    }
    assert!(checkpoint::load(&path).is_err(), "a truncated commit loaded silently");

    // ckpt.commit bitflip: same story for bit-rot.
    {
        let _g = faults::inject(Schedule::parse("ckpt.commit@0:bitflip=33").unwrap());
        checkpoint::save(&tiny_ckpt_state(7), &path, &meta).unwrap();
    }
    assert!(checkpoint::load(&path).is_err(), "a bit-flipped commit loaded silently");
    std::fs::remove_dir_all(dir).ok();
}

// -- serving: supervision, timeouts, shedding ---------------------------------

fn tiny_servable(engine: &Engine, dir: &std::path::Path, seed: u64) -> ServableModel {
    let ckpt = dir.join(format!("sv_{seed}.ckpt"));
    serve::synthesize_quantized_checkpoint(engine, "tinynet", 6, seed, &ckpt).unwrap();
    ServableModel::load(engine, "tinynet", &ckpt, 4, 8).unwrap()
}

#[test]
fn serve_panic_recovery_answers_every_request_exactly_once() {
    let _g = faults::inject(Schedule::parse("serve.batch@2:panic").unwrap());
    let worker_panics = with_deadline(180, "serve exactly-once", move || {
        let engine = Engine::native();
        let dir = scratch("serve_once");
        let sv = tiny_servable(&engine, &dir, 11);
        let (seed, total) = (5u64, 48usize);
        let cfg = PoolConfig::new(2, BatchPolicy::new(8, Duration::from_millis(100)));
        let (stats, responses) = run_closed_loop(&sv, &cfg, total, 16, seed).unwrap();

        assert_eq!(responses.len(), total);
        let mut keys: Vec<_> = responses.iter().map(|r| (r.client, r.index)).collect();
        keys.sort_unstable();
        keys.dedup();
        assert_eq!(keys.len(), total, "a response was dropped or duplicated");
        assert!(responses.iter().all(|r| r.status == ServeStatus::Ok));

        // Every answer — including the re-enqueued batch's — equals a
        // direct single-sample inference, bit for bit.
        let (h, w) = sv.input_hw();
        let c = sv.in_ch();
        for r in &responses {
            let x = serve::synthetic_input(seed, r.client, r.index, sv.sample_elems());
            let direct = sv.infer(Tensor::new(vec![1, h, w, c], x).unwrap()).unwrap();
            for (a, b) in r.logits.iter().zip(direct.data()) {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "request {}/{} served different logits after the panic retry",
                    r.client,
                    r.index
                );
            }
        }
        std::fs::remove_dir_all(dir).ok();
        stats.worker_panics
    });
    assert_eq!(worker_panics, 1, "the injected panic must be caught and counted");
}

#[test]
fn serve_double_panic_fails_fast_instead_of_hanging() {
    // Workers = 1 makes the retry deterministic: the re-enqueued batch is
    // the next serve.batch occurrence, so @2 and @3 hit the same batch.
    let _g = faults::inject(Schedule::parse("serve.batch@2:panic; serve.batch@3:panic").unwrap());
    let err = with_deadline(180, "serve double panic", move || {
        let engine = Engine::native();
        let dir = scratch("serve_twice");
        let sv = tiny_servable(&engine, &dir, 12);
        let cfg = PoolConfig::new(1, BatchPolicy::new(8, Duration::from_millis(50)));
        let out = run_closed_loop(&sv, &cfg, 24, 8, 5).map(|_| ());
        std::fs::remove_dir_all(dir).ok();
        out.unwrap_err()
    });
    assert!(format!("{err:#}").contains("panicked twice"), "{err:#}");
}

#[test]
fn serve_deadline_produces_timeout_responses_not_hangs() {
    let _g = faults::inject(Schedule::default());
    let (timed_out, completed, n) = with_deadline(180, "serve timeouts", move || {
        let engine = Engine::native();
        let dir = scratch("serve_timeout");
        let sv = tiny_servable(&engine, &dir, 13);
        // A zero deadline expires every request at dispatch: the run must
        // still answer each one (TimedOut) and terminate cleanly.
        let cfg = PoolConfig {
            request_timeout: Some(Duration::ZERO),
            ..PoolConfig::new(1, BatchPolicy::new(4, Duration::from_millis(10)))
        };
        let (stats, responses) = run_closed_loop(&sv, &cfg, 16, 4, 3).unwrap();
        assert!(responses.iter().all(|r| r.status == ServeStatus::TimedOut));
        assert!(responses.iter().all(|r| r.logits.is_empty() && r.batch_size == 0));
        assert!(stats.batch_sizes.is_empty(), "no batch should have executed");
        std::fs::remove_dir_all(dir).ok();
        (stats.timed_out, stats.completed, responses.len())
    });
    assert_eq!((timed_out, completed, n), (16, 0, 16));
}

#[test]
fn serve_load_shedding_answers_with_retry_after() {
    // max_batch 1 bounds the request queue at 4; stalling the batcher for
    // two rounds guarantees the 16 concurrent clients overflow it.
    let _g = faults::inject(
        Schedule::parse("serve.batcher@0:delay=150; serve.batcher@1:delay=150").unwrap(),
    );
    let (ok, shed, total) = with_deadline(180, "serve shedding", move || {
        let engine = Engine::native();
        let dir = scratch("serve_shed");
        let sv = tiny_servable(&engine, &dir, 14);
        let retry_after = Duration::from_millis(5);
        let cfg = PoolConfig {
            admission: Admission::Shed { retry_after },
            ..PoolConfig::new(1, BatchPolicy::new(1, Duration::ZERO))
        };
        let total = 32usize;
        let (stats, responses) = run_closed_loop(&sv, &cfg, total, 16, 9).unwrap();
        assert_eq!(responses.len(), total);
        let mut ok = 0usize;
        let mut shed = 0usize;
        for r in &responses {
            match r.status {
                ServeStatus::Ok => ok += 1,
                ServeStatus::Shed { retry_after: ra } => {
                    shed += 1;
                    assert_eq!(ra, retry_after);
                    assert!(r.logits.is_empty() && r.batch_size == 0);
                }
                ServeStatus::TimedOut => panic!("no deadline configured"),
            }
        }
        assert_eq!(stats.shed, shed);
        assert_eq!(stats.completed, ok);
        std::fs::remove_dir_all(dir).ok();
        (ok, shed, total)
    });
    assert!(shed > 0, "a saturated queue must shed");
    assert_eq!(ok + shed, total, "every request answered exactly once");
}
