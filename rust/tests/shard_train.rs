//! Shard-determinism suite for data-parallel training
//! (`runtime::native::shard`, DESIGN.md §10).
//!
//! The contract under test: the native train step is **bit-identical** at
//! any shard count — including counts that do not divide the batch and
//! counts larger than the batch — because every batch-coupled reduction
//! runs at per-sample granularity through a fixed-order tree fold whose
//! shape depends only on the global batch size.

use bsq::coordinator::{run_bsq, BsqConfig};
use bsq::data::{Batch, Corpus, CorpusSpec, Loader};
use bsq::model::{momentum_slots, ModelState};
use bsq::runtime::native::shard::{shard_ranges, tree_fold};
use bsq::runtime::{Engine, RunInputs};
use bsq::tensor::{IntTensor, Tensor};
use bsq::util::Pcg32;

/// Run `steps` train steps of `entry` on a fresh tinynet at `shards`,
/// returning the final state and the per-step (loss, ce, acc, bgl).
fn run_steps(entry: &str, shards: usize, steps: usize) -> (ModelState, Vec<[f32; 4]>) {
    let engine = Engine::native_with_shards(shards);
    let man = engine.manifest("tinynet").unwrap();
    let exe = engine.load(man.artifact(entry).unwrap()).unwrap();

    let mut state = ModelState::init_fp(&man, 7);
    let bit = entry.starts_with("bsq");
    if bit {
        state.to_bit_representation(&man, 8).unwrap();
    }
    state.ensure_momenta(&momentum_slots(&exe.spec.inputs));
    state.check_against(&exe.spec.inputs).unwrap();

    let corpus = Corpus::generate(CorpusSpec::tiny().with_sizes(man.batch * 4, 32));
    let mut loader = Loader::new(&corpus.train, man.batch, Default::default(), 11);
    let mut inputs = RunInputs::default()
        .hyper("lr", 0.05)
        .hyper("wd", 1e-4)
        .vec("actlv", vec![15.0; man.act_sites.len()]);
    if bit {
        inputs = inputs.hyper("alpha", 1e-3).vec("regw", vec![1.0; man.qlayers.len()]);
    }

    let mut metrics = Vec::with_capacity(steps);
    for _ in 0..steps {
        let b = loader.next_batch();
        let out = exe.run(&mut state, Some(&b), &inputs).unwrap();
        metrics.push([
            out.metric("loss").unwrap(),
            out.metric("ce").unwrap(),
            out.metric("acc").unwrap(),
            out.metrics.get("bgl").copied().unwrap_or(0.0),
        ]);
    }
    (state, metrics)
}

fn assert_states_identical(a: &ModelState, b: &ModelState, ctx: &str) {
    let ka: Vec<&String> = a.keys().collect();
    let kb: Vec<&String> = b.keys().collect();
    assert_eq!(ka, kb, "{ctx}: state key sets differ");
    for key in ka {
        let (ta, tb) = (a.get(key).unwrap(), b.get(key).unwrap());
        assert_eq!(ta.shape(), tb.shape(), "{ctx}: {key} shape");
        assert_eq!(ta.data(), tb.data(), "{ctx}: {key} diverged bitwise");
    }
}

/// (a) fp training: loss/gradient effects/updated weights after K steps are
/// bit-identical for shards ∈ {1, 2, 3, 8} — including 3, which does not
/// divide the batch of 16.
#[test]
fn fp_training_is_bit_identical_across_shard_counts() {
    let (ref_state, ref_metrics) = run_steps("fp_train_relu6", 1, 3);
    for shards in [2usize, 3, 8] {
        let (state, metrics) = run_steps("fp_train_relu6", shards, 3);
        assert_eq!(ref_metrics, metrics, "fp metrics diverged at {shards} shards");
        assert_states_identical(&ref_state, &state, &format!("fp shards={shards}"));
    }
}

/// (a) the bit path too: STE plane gradients, scale gradients and the B_GL
/// regularizer all flow through the same canonical reduce.
#[test]
fn bsq_training_is_bit_identical_across_shard_counts() {
    let (ref_state, ref_metrics) = run_steps("bsq_train_relu6", 1, 3);
    for shards in [2usize, 3, 8] {
        let (state, metrics) = run_steps("bsq_train_relu6", shards, 3);
        assert_eq!(ref_metrics, metrics, "bsq metrics diverged at {shards} shards");
        assert_states_identical(&ref_state, &state, &format!("bsq shards={shards}"));
    }
}

/// Empty-shard edge: a batch smaller than the shard count must not spawn
/// empty-range workers — batch=1 with shards=8 trains, and identically to
/// shards=1.
#[test]
fn single_sample_batch_with_more_shards_than_samples() {
    let mut rng = Pcg32::seeded(21);
    let x: Vec<f32> = (0..16 * 16 * 3).map(|_| rng.normal()).collect();
    let batch = Batch {
        x: Tensor::new(vec![1, 16, 16, 3], x).unwrap(),
        y: IntTensor::new(vec![1], vec![3]).unwrap(),
    };

    let mut states = Vec::new();
    for shards in [1usize, 8] {
        let engine = Engine::native_with_shards(shards);
        let man = engine.manifest("tinynet").unwrap();
        let exe = engine.load(man.artifact("fp_train_relu6").unwrap()).unwrap();
        let mut state = ModelState::init_fp(&man, 3);
        state.ensure_momenta(&momentum_slots(&exe.spec.inputs));
        let inputs = RunInputs::default()
            .hyper("lr", 0.05)
            .hyper("wd", 1e-4)
            .vec("actlv", vec![0.0; man.act_sites.len()]);
        for _ in 0..2 {
            let out = exe.run(&mut state, Some(&batch), &inputs).unwrap();
            assert!(out.metric("loss").unwrap().is_finite());
        }
        states.push(state);
    }
    assert_states_identical(&states[0], &states[1], "batch=1 shards 1 vs 8");
}

/// (b) The fixed-order tree reduce: equals a sequential fold wherever f32
/// addition is exact, and its result is a function of the per-sample
/// partials alone — unlike per-shard sequential subtotals, which shift with
/// the partition on adversarial (catastrophically cancelling) inputs.
#[test]
fn tree_fold_is_canonical_on_adversarial_f32_inputs() {
    // exact regime: powers of two — tree and sequential fold agree bitwise
    let exact: Vec<f32> = (0..13).map(|i| (1 << (i % 7)) as f32).collect();
    let tree = tree_fold(exact.clone(), |a, b| *a += *b).unwrap();
    let seq = exact.iter().fold(0.0f32, |s, &v| s + v);
    assert_eq!(tree.to_bits(), seq.to_bits());

    // adversarial regime: large magnitudes with cancellation
    let adversarial: Vec<f32> =
        vec![1.0e8, 1.0, -1.0e8, 3.0e-4, 7.0e7, -7.0e7, 1.0, -1.0, 2.5e-4, 1.0e8, -1.0e8];
    let canon = tree_fold(adversarial.clone(), |a, b| *a += *b).unwrap();
    // the tree is deterministic: same inputs, same bits, every time
    for _ in 0..10 {
        let again = tree_fold(adversarial.clone(), |a, b| *a += *b).unwrap();
        assert_eq!(canon.to_bits(), again.to_bits());
    }
    // whereas folding per-shard subtotals shifts with the partition — the
    // reason gradients reduce at sample granularity, never shard granularity
    let partition_fold = |chunks: &[&[f32]]| -> f32 {
        chunks.iter().map(|c| c.iter().fold(0.0f32, |s, &v| s + v)).fold(0.0, |s, v| s + v)
    };
    let two = partition_fold(&[&adversarial[..4], &adversarial[4..]]);
    let three = partition_fold(&[&adversarial[..3], &adversarial[3..7], &adversarial[7..]]);
    assert_ne!(
        two.to_bits(),
        three.to_bits(),
        "expected the adversarial inputs to expose partition-dependent rounding"
    );
}

/// Shard planning: contiguous cover, never an empty range, balanced to
/// within one sample (regression for the empty-shard edge).
#[test]
fn shard_ranges_are_total_and_never_empty() {
    for (samples, shards) in [(1usize, 8usize), (16, 3), (16, 16), (16, 40), (2, 2), (9, 4)] {
        let ranges = shard_ranges(samples, shards);
        assert!(!ranges.is_empty());
        assert!(ranges.iter().all(|r| !r.is_empty()), "{samples}/{shards}: {ranges:?}");
        assert_eq!(ranges.first().unwrap().start, 0);
        assert_eq!(ranges.last().unwrap().end, samples);
        for w in ranges.windows(2) {
            assert_eq!(w[0].end, w[1].start);
        }
    }
}

/// (c) The full pipeline: `run_bsq` at shards=4 reproduces the shards=1
/// per-epoch bit-group-length (bgl) and loss trajectory exactly, along with
/// the final per-layer precision scheme.
#[test]
fn run_bsq_trajectory_is_identical_at_4_shards() {
    let mut cfg = BsqConfig::for_model("tinynet");
    cfg.pretrain_epochs = 1;
    cfg.bsq_epochs = 2;
    cfg.finetune_epochs = 1;
    cfg.requant_interval = 1;
    cfg.train_size = 96;
    cfg.test_size = 48;
    cfg.eval_batches = 2;
    cfg.alpha = 1e-4;
    cfg.cache_pretrained = false; // a cached fp checkpoint would mask drift

    let base = run_bsq(&Engine::native_with_shards(1), &cfg).unwrap();
    let sharded = run_bsq(&Engine::native_with_shards(4), &cfg).unwrap();

    assert_eq!(base.scheme.bits_vec(), sharded.scheme.bits_vec());
    assert_eq!(base.acc_before_ft.to_bits(), sharded.acc_before_ft.to_bits());
    assert_eq!(base.acc_after_ft.to_bits(), sharded.acc_after_ft.to_bits());
    assert_eq!(base.history.records.len(), sharded.history.records.len());
    for (a, b) in base.history.records.iter().zip(&sharded.history.records) {
        assert_eq!(a.phase, b.phase);
        assert_eq!(a.epoch, b.epoch);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "[{}] epoch {} loss", a.phase, a.epoch);
        assert_eq!(a.bgl.to_bits(), b.bgl.to_bits(), "[{}] epoch {} bgl", a.phase, a.epoch);
        assert_eq!(a.acc.to_bits(), b.acc.to_bits(), "[{}] epoch {} acc", a.phase, a.epoch);
        assert_eq!(
            a.bits_per_param.to_bits(),
            b.bits_per_param.to_bits(),
            "[{}] epoch {} bits/param",
            a.phase,
            a.epoch
        );
    }
}
