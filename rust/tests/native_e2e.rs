//! Integration: the full pipeline through `runtime::native` — no AOT
//! artifacts, no PJRT, no Python. These are the native counterparts of
//! `runtime_e2e.rs` / `pipeline_e2e.rs` (which stay gated on disk
//! artifacts for the real-XLA path).

use bsq::baselines::{self, HawqConfig, QatConfig};
use bsq::coordinator::{run_bsq, BsqConfig, Session};
use bsq::data::{Corpus, CorpusSpec, Loader};
use bsq::model::{momentum_slots, ModelState};
use bsq::quant::{reg_weights, QuantScheme, Reweigh};
use bsq::runtime::{Engine, RunInputs};

fn tiny_cfg() -> BsqConfig {
    let mut cfg = BsqConfig::for_model("tinynet");
    cfg.pretrain_epochs = 2;
    cfg.bsq_epochs = 3;
    cfg.finetune_epochs = 1;
    cfg.requant_interval = 1;
    cfg.train_size = 128;
    cfg.test_size = 64;
    cfg.eval_batches = 2;
    cfg.alpha = 1e-4; // tinynet scale (≈50× below the resnet20 α axis)
    cfg.cache_pretrained = false;
    cfg
}

#[test]
fn fp_train_step_decreases_loss() {
    let engine = Engine::cpu().unwrap();
    assert!(engine.is_native(), "offline build must come up on the native backend");
    let man = engine.manifest("tinynet").unwrap();
    let exe = engine.load(man.artifact("fp_train_relu6").unwrap()).unwrap();

    let mut state = ModelState::init_fp(&man, 0);
    state.ensure_momenta(&momentum_slots(&exe.spec.inputs));
    state.check_against(&exe.spec.inputs).unwrap();

    let corpus = Corpus::generate(CorpusSpec::tiny().with_sizes(man.batch * 4, 64));
    let mut loader = Loader::new(&corpus.train, man.batch, Default::default(), 1);
    let inputs = RunInputs::default()
        .hyper("lr", 0.05)
        .hyper("wd", 1e-4)
        .vec("actlv", vec![0.0; man.act_sites.len()]);

    let mut losses = vec![];
    for _ in 0..8 {
        let batch = loader.next_batch();
        let out = exe.run(&mut state, Some(&batch), &inputs).unwrap();
        losses.push(out.metric("loss").unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
}

#[test]
fn bsq_train_shrinks_plane_norms_and_evals() {
    let engine = Engine::native();
    let man = engine.manifest("tinynet").unwrap();
    let train = engine.load(man.artifact("bsq_train_relu6").unwrap()).unwrap();
    let eval = engine.load(man.artifact("q_eval_relu6").unwrap()).unwrap();

    let mut state = ModelState::init_fp(&man, 7);
    state.to_bit_representation(&man, 8).unwrap();
    state.ensure_momenta(&momentum_slots(&train.spec.inputs));
    state.check_against(&train.spec.inputs).unwrap();

    let scheme = {
        let bits = state.bits_by_layer(&man).unwrap();
        QuantScheme::new(
            man.qlayers
                .iter()
                .zip(bits)
                .map(|(q, b)| bsq::quant::LayerPrec {
                    name: q.name.clone(),
                    params: q.params,
                    bits: b,
                })
                .collect(),
        )
    };
    assert_eq!(scheme.bits_per_param(), 8.0);

    let corpus = Corpus::generate(CorpusSpec::tiny().with_sizes(man.batch * 4, man.batch * 2));
    let mut loader = Loader::new(&corpus.train, man.batch, Default::default(), 2);
    let regw = reg_weights(&scheme, Reweigh::MemoryAware);
    let actlv = vec![15.0; man.act_sites.len()];
    let inputs = RunInputs::default()
        .hyper("lr", 0.05)
        .hyper("wd", 1e-4)
        .hyper("alpha", 1e-2)
        .vec("regw", regw)
        .vec("actlv", actlv.clone());

    let mut bgl = vec![];
    for _ in 0..6 {
        let b = loader.next_batch();
        let out = train.run(&mut state, Some(&b), &inputs).unwrap();
        bgl.push(out.metric("bgl").unwrap());
        assert!(out.metric("loss").unwrap().is_finite());
    }
    // regularizer pressure must shrink the plane norms
    assert!(bgl.last().unwrap() < bgl.first().unwrap(), "{bgl:?}");

    // planes stayed clamped in [0, 2]
    for q in &man.qlayers {
        let wp = state.get(&format!("wp:{}", q.name)).unwrap();
        assert!(wp.data().iter().all(|&v| (0.0..=2.0).contains(&v)));
    }

    // eval runs on the same state, through the bit-plane GEMM path
    let mut ev = Loader::eval(&corpus.test, man.batch);
    let einputs = RunInputs::default().vec("actlv", actlv);
    let out = eval.run(&mut state, Some(&ev.next_batch()), &einputs).unwrap();
    let acc = out.metric("acc").unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn requantization_does_not_change_eval_loss() {
    // Paper §3.3: sWq is unchanged by re-quantization + precision
    // adjustment, so the (bit-plane GEMM) eval loss must agree before and
    // after, up to the f32 scale store.
    let engine = Engine::native();
    let man = engine.manifest("tinynet").unwrap();
    let eval = engine.load(man.artifact("q_eval_relu6").unwrap()).unwrap();

    let mut state = ModelState::init_fp(&man, 21);
    state.to_bit_representation(&man, 8).unwrap();

    let corpus = Corpus::generate(CorpusSpec::tiny().with_sizes(64, man.batch));
    let mut ev = Loader::eval(&corpus.test, man.batch);
    let batch = ev.next_batch();
    let inputs = RunInputs::default().vec("actlv", vec![15.0; man.act_sites.len()]);

    let before = eval.run(&mut state, Some(&batch), &inputs).unwrap().metric("loss").unwrap();
    for q in &man.qlayers {
        let mut rep = state.bitrep(&q.name).unwrap();
        bsq::quant::requantize(&mut rep);
        state.install_bitrep(&q.name, rep);
    }
    let after = eval.run(&mut state, Some(&batch), &inputs).unwrap().metric("loss").unwrap();
    assert!(
        (before - after).abs() < 1e-3 * before.abs().max(1.0),
        "requantization changed eval loss: {before} → {after}"
    );
}

#[test]
fn run_bsq_tiny_executes_end_to_end() {
    // The acceptance path: the full pipeline (pretrain → BSQ → requant →
    // finetune) on the tiny() synthetic profile, entirely on the native
    // backend — no stub error anywhere.
    let engine = Engine::cpu().unwrap();
    let outcome = run_bsq(&engine, &tiny_cfg()).unwrap();

    assert_eq!(outcome.scheme.layers.len(), 4);
    assert!(outcome.scheme.layers.iter().all(|l| l.bits <= 9));
    assert!(outcome.bits_per_param >= 0.0 && outcome.bits_per_param <= 9.0);
    assert!((0.0..=1.0).contains(&outcome.acc_before_ft));
    assert!((0.0..=1.0).contains(&outcome.acc_after_ft));
    assert!(outcome.compression.is_finite() || outcome.bits_per_param == 0.0);
    for phase in ["pretrain", "bsq", "finetune"] {
        assert!(outcome.history.last_of(phase).is_some(), "missing {phase}");
    }
}

#[test]
fn dorefa_from_scratch_runs_natively() {
    let engine = Engine::native();
    let session = Session::open(&engine, "tinynet", 128, 64, 0).unwrap();
    let names: Vec<(String, usize)> =
        session.man.qlayers.iter().map(|q| (q.name.clone(), q.params)).collect();
    let scheme = QuantScheme::uniform(&names, 3);
    let out =
        baselines::dorefa::train_from_scratch(&session, &scheme, &QatConfig::from_scratch(4, 4, 0))
            .unwrap();
    assert!(out.final_acc.is_finite());
    // collapse guard, not a benchmark: random is 0.10 on 10 classes
    assert!(out.final_acc > 0.05, "dorefa collapsed: {}", out.final_acc);
    assert!(out.best_acc >= out.final_acc);
}

#[test]
fn hawq_power_iteration_ranks_layers_natively() {
    let engine = Engine::native();
    let session = Session::open(&engine, "tinynet", 128, 64, 0).unwrap();
    let state = ModelState::init_fp(&session.man, 3);
    let report = baselines::hawq::analyze(
        &session,
        &state,
        &HawqConfig { power_iters: 4, batches: 1, seed: 1 },
    )
    .unwrap();
    assert_eq!(report.eigenvalues.len(), 4);
    assert!(report.eigenvalues.iter().all(|l| l.is_finite() && *l >= 0.0));
    let mut r = report.ranking.clone();
    r.sort();
    assert_eq!(r, vec![0, 1, 2, 3]);

    let scheme = baselines::hawq::assign_scheme(&session, &report, 4.0, &[8, 4, 2]);
    assert!(scheme.bits_per_param() > 1.0 && scheme.bits_per_param() < 9.0);
}
