//! Seeded property-test harness for the quantization engine (no external
//! crates — `util::rng::Pcg32` drives a hand-rolled generator).
//!
//! Two properties, ~200 randomized cases each, sweeping random shapes
//! (including the 63/64/65/128/130 word boundaries), scales, continuous
//! mid-training plane values, and plane-trim masks (bottom-packed *and*
//! gapped):
//!
//! 1. **Packed ⇄ reference bit-identity** — every packed-engine routine
//!    (`to_bitplanes`, `integer_codes`, `from_bitplanes`, `requantize`)
//!    reproduces the retained scalar path in `quant::reference` bit for
//!    bit: same codes, same planes, same masks, same scale *bits*.
//! 2. **Re-quantization idempotence** — `requantize(requantize(x))` is a
//!    no-op on the planes/mask and moves the scale by at most the one
//!    f64→f32 store ulp (`requantize(requantize(x)) == requantize(x)`).
//!
//! Everything is keyed off fixed seeds, so two consecutive `cargo test`
//! runs produce identical results — the CI gate runs this under
//! `--release` to keep the sweeps fast.

use bsq::quant::bitplane::integer_codes;
use bsq::quant::{from_bitplanes, reference, requantize, to_bitplanes, BitRep, NB};
use bsq::tensor::Tensor;
use bsq::util::Pcg32;

const CASES: usize = 200;

/// Random element count, biased toward u64-word boundaries.
fn random_elems(rng: &mut Pcg32) -> usize {
    const EDGES: [usize; 9] = [1, 2, 7, 63, 64, 65, 127, 128, 130];
    if rng.bool(0.4) {
        EDGES[rng.below(EDGES.len() as u32) as usize]
    } else {
        1 + rng.below(200) as usize
    }
}

/// Random 1-D or 2-D weight shape with the given element count flavor.
fn random_shape(rng: &mut Pcg32) -> Vec<usize> {
    let elems = random_elems(rng);
    if rng.bool(0.3) && elems % 2 == 0 {
        vec![2, elems / 2]
    } else {
        vec![elems]
    }
}

/// A mid-training-flavored `BitRep`: quantized random weights whose planes
/// are then perturbed into continuous `[0, 2]` values, with a random scale
/// and (sometimes) a gapped plane mask or a dead layer.
fn random_rep(rng: &mut Pcg32) -> BitRep {
    let shape = random_shape(rng);
    let n = 1 + rng.below(8) as usize;
    let w = Tensor::randn(&shape, rng.range(0.05, 1.5), rng);
    let mut rep = to_bitplanes(&w, n).unwrap();
    for v in rep.wp.data_mut().iter_mut().chain(rep.wn.data_mut()) {
        *v = (*v + rng.range(-0.45, 0.45)).clamp(0.0, 2.0);
    }
    rep.scale = rng.range(0.01, 4.0);
    if rng.bool(0.15) {
        // gapped plane-trim mask: any subset of planes may be active (at
        // least one — an all-zero mask is the dead-layer no-op, covered
        // separately below)
        let mut m = vec![0.0f32; NB];
        for slot in m.iter_mut() {
            if rng.bool(0.5) {
                *slot = 1.0;
            }
        }
        if m.iter().all(|&x| x == 0.0) {
            m[0] = 1.0;
        }
        rep.mask = Tensor::new(vec![NB], m).unwrap();
    }
    if rng.bool(0.04) {
        // dead layer: every plane zero (the large-α pruning regime)
        rep.wp.data_mut().fill(0.0);
        rep.wn.data_mut().fill(0.0);
    }
    rep
}

fn assert_tensors_bit_equal(a: &Tensor, b: &Tensor, what: &str, case: usize) {
    assert_eq!(a.shape(), b.shape(), "case {case}: {what} shapes");
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "case {case}: {what}[{i}]: {x} vs {y}");
    }
}

#[test]
fn prop_packed_matches_reference_bit_for_bit() {
    let mut rng = Pcg32::seeded(0xB50);
    for case in 0..CASES {
        let rep = random_rep(&mut rng);

        // code extraction
        let packed_codes = integer_codes(&rep);
        let ref_codes = reference::integer_codes(&rep);
        assert_eq!(packed_codes, ref_codes, "case {case}: integer_codes");

        // reconstruction
        let packed_w = from_bitplanes(&rep);
        let ref_w = reference::from_bitplanes(&rep);
        assert_tensors_bit_equal(&packed_w, &ref_w, "from_bitplanes", case);

        // re-quantization + precision adjustment
        let mut packed_rep = rep.clone();
        let mut ref_rep = rep.clone();
        let pr = requantize(&mut packed_rep);
        let rr = reference::requantize(&mut ref_rep);
        assert_eq!(pr, rr, "case {case}: AdjustReport");
        assert_tensors_bit_equal(&packed_rep.wp, &ref_rep.wp, "requantized wp", case);
        assert_tensors_bit_equal(&packed_rep.wn, &ref_rep.wn, "requantized wn", case);
        assert_tensors_bit_equal(&packed_rep.mask, &ref_rep.mask, "requantized mask", case);
        assert_eq!(
            packed_rep.scale.to_bits(),
            ref_rep.scale.to_bits(),
            "case {case}: requantized scale {} vs {}",
            packed_rep.scale,
            ref_rep.scale
        );
    }
}

#[test]
fn prop_to_bitplanes_matches_reference() {
    let mut rng = Pcg32::seeded(0x70B1);
    for case in 0..CASES {
        let shape = random_shape(&mut rng);
        let n = 1 + rng.below(8) as usize;
        let w = Tensor::randn(&shape, rng.range(0.05, 2.0), &mut rng);
        let packed = to_bitplanes(&w, n).unwrap();
        let refr = reference::to_bitplanes(&w, n).unwrap();
        assert_tensors_bit_equal(&packed.wp, &refr.wp, "to_bitplanes wp", case);
        assert_tensors_bit_equal(&packed.wn, &refr.wn, "to_bitplanes wn", case);
        assert_tensors_bit_equal(&packed.mask, &refr.mask, "to_bitplanes mask", case);
        assert_eq!(packed.scale.to_bits(), refr.scale.to_bits(), "case {case}: scale");
    }
}

#[test]
fn prop_requantize_idempotent() {
    let mut rng = Pcg32::seeded(0x1DE0);
    for case in 0..CASES {
        let mut rep = random_rep(&mut rng);
        requantize(&mut rep);
        let wp = rep.wp.clone();
        let wn = rep.wn.clone();
        let mask = rep.mask.clone();
        let scale = rep.scale;

        let r2 = requantize(&mut rep);
        assert_eq!(
            r2.bits_before, r2.bits_after,
            "case {case}: second adjustment changed precision"
        );
        assert_eq!(r2.lsb_trimmed, 0, "case {case}: second adjustment trimmed LSBs");
        assert_tensors_bit_equal(&rep.wp, &wp, "idempotent wp", case);
        assert_tensors_bit_equal(&rep.wn, &wn, "idempotent wn", case);
        assert_tensors_bit_equal(&rep.mask, &mask, "idempotent mask", case);
        // scale: the only rounding is the f64→f32 store (≤ 1 ulp per pass)
        assert!(
            (rep.scale - scale).abs() <= 1e-6 * scale.abs().max(1e-6),
            "case {case}: scale drifted {} → {}",
            scale,
            rep.scale
        );

        // the adjusted layer is canonical: bottom-packed mask, binary
        // planes, and (unless dead) an occupied LSB plane
        let n_after = rep.bits();
        let m = rep.mask.data();
        assert!(m.iter().take(n_after).all(|&x| x == 1.0), "case {case}");
        assert!(m.iter().skip(n_after).all(|&x| x == 0.0), "case {case}");
        assert!(rep.wp.data().iter().all(|&v| v == 0.0 || v == 1.0), "case {case}");
        assert!(rep.wn.data().iter().all(|&v| v == 0.0 || v == 1.0), "case {case}");
        let packed = rep.pack();
        assert_eq!(
            packed.effective_bits(),
            n_after,
            "case {case}: effective bits disagree with the adjusted mask"
        );
    }
}

#[test]
fn prop_requantize_preserves_represented_weight() {
    // Paper Eq. 6: δ·V is invariant across the adjustment (codes shift
    // exactly; only the f32 scale store rounds).
    let mut rng = Pcg32::seeded(0xE06);
    for case in 0..CASES {
        let rep0 = random_rep(&mut rng);
        let before = from_bitplanes(&rep0);
        let mut rep = rep0;
        requantize(&mut rep);
        let after = from_bitplanes(&rep);
        for (i, (a, b)) in before.data().iter().zip(after.data()).enumerate() {
            let tol = 1e-5 * a.abs().max(1e-5);
            assert!((a - b).abs() <= tol, "case {case} elem {i}: {a} vs {b}");
        }
    }
}
