//! Integration tests for the serving layer and the guarantees it leans on:
//! checkpoint round-trips are bit-exact, one engine/servable is safe to
//! share across threads (bit-identical outputs), and the batcher → worker
//! pool answers every request with exactly what a single-sample inference
//! would have produced (batch-composition independence).
//!
//! Deterministic under fixed seeds; CI runs this under `--release`.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use bsq::data::{Corpus, CorpusSpec, Loader};
use bsq::model::{checkpoint, ModelState};
use bsq::runtime::{Engine, RunInputs};
use bsq::serve::{
    self, run_closed_loop, synthetic_input, BatchPolicy, PoolConfig, Registry, ServableModel,
};
use bsq::tensor::Tensor;
use bsq::util::{Json, Pcg32};

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bsq_serve_e2e_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn tiny_servable(engine: &Engine, dir: &std::path::Path, seed: u64) -> ServableModel {
    let ckpt = dir.join(format!("tiny_s{seed}.ckpt"));
    serve::synthesize_quantized_checkpoint(engine, "tinynet", 6, seed, &ckpt).unwrap();
    ServableModel::load(engine, "tinynet", &ckpt, 4, 8).unwrap()
}

fn random_batch(rng: &mut Pcg32, m: usize, sv: &ServableModel) -> Tensor {
    let (h, w) = sv.input_hw();
    let c = sv.in_ch();
    let data: Vec<f32> = (0..m * h * w * c).map(|_| rng.normal()).collect();
    Tensor::new(vec![m, h, w, c], data).unwrap()
}

#[test]
fn checkpoint_roundtrip_is_bit_identical() {
    let engine = Engine::native();
    let dir = scratch("rt");
    let path_a = dir.join("a.ckpt");
    serve::synthesize_quantized_checkpoint(&engine, "tinynet", 6, 3, &path_a).unwrap();

    // save → load → save: the reloaded state serves identically
    let state = checkpoint::load(&path_a).unwrap();
    let path_b = dir.join("b.ckpt");
    checkpoint::save(&state, &path_b, &Json::obj(vec![("phase", Json::str("rt"))])).unwrap();

    let reg = Registry::new(&engine);
    let a = reg.load("tinynet", &path_a, 4, 8).unwrap();
    let b = reg.load("tinynet", &path_b, 4, 8).unwrap();

    // identical per-layer precision map
    assert_eq!(a.layers, b.layers);
    assert_eq!(a.weight_bits(), b.weight_bits());

    // bit-identical logits through the serving path
    let mut rng = Pcg32::seeded(5);
    let x = random_batch(&mut rng, 4, a.as_ref());
    let la = a.infer(x.clone()).unwrap();
    let lb = b.infer(x).unwrap();
    for (p, q) in la.data().iter().zip(lb.data()) {
        assert_eq!(p.to_bits(), q.to_bits());
    }

    // and through the engine's q_eval artifact: same loss/acc bits
    let man = engine.manifest("tinynet").unwrap();
    let exe = engine.load(man.artifact("q_eval_relu6").unwrap()).unwrap();
    let corpus = Corpus::generate(CorpusSpec::tiny().with_sizes(64, 32));
    let batch = Loader::eval(&corpus.test, man.batch).next_batch();
    let inputs = RunInputs::default().vec("actlv", vec![255.0, 15.0, 255.0]);
    let mut sa = checkpoint::load(&path_a).unwrap();
    let mut sb = checkpoint::load(&path_b).unwrap();
    let oa = exe.run(&mut sa, Some(&batch), &inputs).unwrap();
    let ob = exe.run(&mut sb, Some(&batch), &inputs).unwrap();
    for key in ["loss", "acc"] {
        assert_eq!(
            oa.metric(key).unwrap().to_bits(),
            ob.metric(key).unwrap().to_bits(),
            "{key} drifted across the checkpoint round-trip"
        );
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn engine_eval_is_deterministic_across_eight_threads() {
    // One Engine + one Arc<Executable> shared across 8 scoped threads, each
    // evaluating the same batch on its own copy of the same state, must
    // produce bit-identical metrics — the guard on the Arc<Executable>
    // cache and the serve worker pool sharing one engine.
    let engine = Engine::native();
    let man = engine.manifest("tinynet").unwrap();
    let exe = engine.load(man.artifact("q_eval_relu6").unwrap()).unwrap();

    let mut base = ModelState::init_fp(&man, 11);
    base.to_bit_representation(&man, 8).unwrap();
    let corpus = Corpus::generate(CorpusSpec::tiny().with_sizes(64, 32));
    let batch = Loader::eval(&corpus.test, man.batch).next_batch();
    let inputs = RunInputs::default().vec("actlv", vec![255.0, 15.0, 255.0]);

    let results: Vec<(u32, u32)> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let (exe, base, batch, inputs) = (&exe, &base, &batch, &inputs);
                s.spawn(move || {
                    let mut state = base.clone();
                    let out = exe.run(&mut state, Some(batch), inputs).unwrap();
                    (
                        out.metric("loss").unwrap().to_bits(),
                        out.metric("acc").unwrap().to_bits(),
                    )
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(
        results.windows(2).all(|w| w[0] == w[1]),
        "threads disagreed: {results:?}"
    );

    // the second load of the same artifact is the same cached executable
    let again = engine.load(man.artifact("q_eval_relu6").unwrap()).unwrap();
    assert!(Arc::ptr_eq(&exe, &again));
}

#[test]
fn servable_inference_is_batch_invariant_and_thread_deterministic() {
    let engine = Engine::native();
    let dir = scratch("inv");
    let sv = tiny_servable(&engine, &dir, 7);
    let mut rng = Pcg32::seeded(21);
    let x = random_batch(&mut rng, 6, &sv);
    let full = sv.infer(x.clone()).unwrap();
    let classes = sv.num_classes();

    // per-sample rows are independent of batch composition
    let (h, w) = sv.input_hw();
    let c = sv.in_ch();
    let pix = h * w * c;
    for i in 0..6 {
        let xi =
            Tensor::new(vec![1, h, w, c], x.data()[i * pix..(i + 1) * pix].to_vec()).unwrap();
        let row = sv.infer(xi).unwrap();
        for (a, b) in row.data().iter().zip(&full.data()[i * classes..(i + 1) * classes]) {
            assert_eq!(a.to_bits(), b.to_bits(), "sample {i} changed with batch size");
        }
    }

    // 8 threads over the same immutable servable agree bit for bit
    let logits: Vec<Vec<u32>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let (sv, x) = (&sv, &x);
                s.spawn(move || {
                    sv.infer(x.clone()).unwrap().data().iter().map(|v| v.to_bits()).collect()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert!(logits.windows(2).all(|w| w[0] == w[1]));
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn closed_loop_serving_answers_every_request_exactly() {
    let engine = Engine::native();
    let dir = scratch("loop");
    let sv = tiny_servable(&engine, &dir, 9);
    let seed = 13u64;
    let total = 48;
    let cfg = PoolConfig::new(4, BatchPolicy::new(8, Duration::from_millis(200)));
    let (stats, responses) = run_closed_loop(&sv, &cfg, total, 16, seed).unwrap();

    assert_eq!(stats.completed, total);
    assert_eq!(responses.len(), total);
    assert_eq!(stats.batch_sizes.iter().sum::<usize>(), total);
    assert!(stats.batch_sizes.iter().all(|&b| (1..=8).contains(&b)));
    assert!(stats.wall > Duration::ZERO);
    assert_eq!(stats.weight_bits_per_sample, sv.weight_bits());
    let summary = stats.summary();
    assert!(summary.throughput_rps > 0.0);
    assert!(summary.p50_us > 0.0 && summary.p99_us >= summary.p50_us);

    // every served answer equals a direct single-sample inference of the
    // same request payload — batching must never change results
    let (h, w) = sv.input_hw();
    let c = sv.in_ch();
    for r in &responses {
        let x = synthetic_input(seed, r.client, r.index, sv.sample_elems());
        let direct = sv.infer(Tensor::new(vec![1, h, w, c], x).unwrap()).unwrap();
        assert_eq!(r.logits.len(), direct.len());
        for (a, b) in r.logits.iter().zip(direct.data()) {
            assert_eq!(
                a.to_bits(),
                b.to_bits(),
                "request {}/{} served different logits than direct inference",
                r.client,
                r.index
            );
        }
        let want = direct
            .data()
            .iter()
            .enumerate()
            .max_by(|p, q| p.1.total_cmp(q.1))
            .map(|(i, _)| i)
            .unwrap();
        assert_eq!(r.argmax, want);
    }

    // two runs under the same seed serve identical payloads
    let (_, responses2) = run_closed_loop(&sv, &cfg, total, 16, seed).unwrap();
    let key = |r: &serve::ServeResponse| (r.client, r.index);
    let mut a: Vec<_> = responses.iter().map(|r| (key(r), r.argmax)).collect();
    let mut b: Vec<_> = responses2.iter().map(|r| (key(r), r.argmax)).collect();
    a.sort();
    b.sort();
    assert_eq!(a, b);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn sweep_covers_grid_and_records_full_completion() {
    let engine = Engine::native();
    let dir = scratch("sweep");
    let sv = tiny_servable(&engine, &dir, 1);
    let cells =
        serve::sweep(&sv, &[1, 4], &[1, 2], 24, Duration::from_millis(5), 0).unwrap();
    assert_eq!(cells.len(), 4);
    for cell in &cells {
        assert_eq!(cell.summary.completed, 24);
        assert!(cell.summary.throughput_rps > 0.0);
        assert!(cell.summary.max_batch_observed <= cell.max_batch);
    }
    let json = serve::sweep_json(&sv, &cells);
    assert_eq!(json.req("target").unwrap().as_str().unwrap(), "serve");
    assert_eq!(json.req("cells").unwrap().as_arr().unwrap().len(), 4);
    assert_eq!(json.req("layers").unwrap().as_arr().unwrap().len(), 4);
    // speedup keys exist per worker count (batch 4 over batch 1)
    let sp = json.req("speedups").unwrap().as_obj().unwrap();
    assert_eq!(sp.len(), 2);
    std::fs::remove_dir_all(dir).ok();
}
