//! Integration: real AOT artifacts through the PJRT runtime.
//!
//! Requires `make artifacts` (skips gracefully otherwise). Exercises the
//! full contract: manifest load → compile → state init → train/eval steps →
//! metric extraction → output writeback — i.e. exactly what the coordinator
//! does, on the tinynet model.

use bsq::data::{Corpus, CorpusSpec, Loader};
use bsq::model::{momentum_slots, ModelState};
use bsq::quant::{reg_weights, QuantScheme, Reweigh};
use bsq::runtime::{load_manifest, Engine, RunInputs};

fn have_artifacts() -> bool {
    bsq::runtime::artifacts_root().join("tinynet/manifest.json").exists()
}

fn scheme_from_state(man: &bsq::runtime::Manifest, state: &ModelState) -> QuantScheme {
    let bits = state.bits_by_layer(man).unwrap();
    QuantScheme::new(
        man.qlayers
            .iter()
            .zip(bits)
            .map(|(q, b)| bsq::quant::LayerPrec { name: q.name.clone(), params: q.params, bits: b })
            .collect(),
    )
}

#[test]
fn fp_train_step_decreases_loss() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let man = load_manifest("tinynet").unwrap();
    let engine = Engine::cpu().unwrap();
    let exe = engine.load(man.artifact("fp_train_relu6").unwrap()).unwrap();

    let mut state = ModelState::init_fp(&man, 0);
    state.ensure_momenta(&momentum_slots(&exe.spec.inputs));
    state.check_against(&exe.spec.inputs).unwrap();

    let corpus = Corpus::generate(CorpusSpec::tiny().with_sizes(man.batch * 4, 64));
    let mut loader = Loader::new(&corpus.train, man.batch, Default::default(), 1);
    let inputs = RunInputs::default()
        .hyper("lr", 0.05)
        .hyper("wd", 1e-4)
        .vec("actlv", vec![0.0; man.act_sites.len()]);

    let mut losses = vec![];
    for _ in 0..8 {
        let batch = loader.next_batch();
        let out = exe.run(&mut state, Some(&batch), &inputs).unwrap();
        losses.push(out.metric("loss").unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()), "{losses:?}");
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
}

#[test]
fn bsq_train_and_eval_roundtrip() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let man = load_manifest("tinynet").unwrap();
    let engine = Engine::cpu().unwrap();
    let train = engine.load(man.artifact("bsq_train_relu6").unwrap()).unwrap();
    let eval = engine.load(man.artifact("q_eval_relu6").unwrap()).unwrap();

    // fp init → bit representation at 8 bits
    let mut state = ModelState::init_fp(&man, 7);
    state.to_bit_representation(&man, 8).unwrap();
    state.ensure_momenta(&momentum_slots(&train.spec.inputs));
    state.check_against(&train.spec.inputs).unwrap();

    let scheme = scheme_from_state(&man, &state);
    assert_eq!(scheme.bits_per_param(), 8.0);

    let corpus = Corpus::generate(CorpusSpec::tiny().with_sizes(man.batch * 4, man.batch * 2));
    let mut loader = Loader::new(&corpus.train, man.batch, Default::default(), 2);
    let regw = reg_weights(&scheme, Reweigh::MemoryAware);
    let actlv = vec![15.0; man.act_sites.len()];
    let inputs = RunInputs::default()
        .hyper("lr", 0.05)
        .hyper("wd", 1e-4)
        .hyper("alpha", 1e-2)
        .vec("regw", regw)
        .vec("actlv", actlv.clone());

    let mut bgl = vec![];
    for _ in 0..6 {
        let b = loader.next_batch();
        let out = train.run(&mut state, Some(&b), &inputs).unwrap();
        bgl.push(out.metric("bgl").unwrap());
        assert!(out.metric("loss").unwrap().is_finite());
    }
    // regularizer pressure must shrink the plane norms
    assert!(bgl.last().unwrap() < bgl.first().unwrap(), "{bgl:?}");

    // planes stayed clamped in [0, 2]
    for q in &man.qlayers {
        let wp = state.get(&format!("wp:{}", q.name)).unwrap();
        assert!(wp.data().iter().all(|&v| (0.0..=2.0).contains(&v)));
    }

    // eval runs on the same state
    let mut ev = Loader::eval(&corpus.test, man.batch);
    let einputs = RunInputs::default().vec("actlv", actlv);
    let out = eval.run(&mut state, Some(&ev.next_batch()), &einputs).unwrap();
    let acc = out.metric("acc").unwrap();
    assert!((0.0..=1.0).contains(&acc));
}

#[test]
fn requantization_does_not_change_eval_loss() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    // Paper §3.3: sWq is unchanged by re-quantization + precision adjustment,
    // so the eval loss before and after must agree (up to f32 scale rounding).
    let man = load_manifest("tinynet").unwrap();
    let engine = Engine::cpu().unwrap();
    let eval = engine.load(man.artifact("q_eval_relu6").unwrap()).unwrap();

    let mut state = ModelState::init_fp(&man, 21);
    state.to_bit_representation(&man, 8).unwrap();

    let corpus = Corpus::generate(CorpusSpec::tiny().with_sizes(64, man.batch));
    let mut ev = Loader::eval(&corpus.test, man.batch);
    let batch = ev.next_batch();
    let inputs = RunInputs::default().vec("actlv", vec![15.0; man.act_sites.len()]);

    let before = eval.run(&mut state, Some(&batch), &inputs).unwrap().metric("loss").unwrap();
    for q in &man.qlayers {
        let mut rep = state.bitrep(&q.name).unwrap();
        bsq::quant::requantize(&mut rep);
        state.install_bitrep(&q.name, rep);
    }
    let after = eval.run(&mut state, Some(&batch), &inputs).unwrap().metric("loss").unwrap();
    assert!(
        (before - after).abs() < 1e-4 * before.abs().max(1.0),
        "requantization changed eval loss: {before} → {after}"
    );
}
