//! Differential tests for `tensor::gemm`: the blocked dense kernels, the
//! im2col convolution lowering and the bit-plane GEMM are all checked
//! against a naive f64 reference across randomized shapes, sign patterns,
//! word-boundary sizes and 0–8 trimmed planes.
//!
//! The SIMD sections (skipped when the host lacks AVX2/FMA or
//! `BSQ_FORCE_SCALAR=1` pins the scalar backend) hold the dispatch
//! contract of DESIGN.md §13: dense SIMD agrees with scalar within 1e-4
//! relative (FMA rounding), bit-plane SIMD is **bitwise** equal to scalar,
//! and SIMD results are bitwise stable across repeats, thread caps,
//! emulated shard row-partitions, batch sizes, and every remainder-tile
//! residue of the 8×8 register block.

use bsq::quant::{requantize, to_bitplanes};
use bsq::tensor::gemm::{
    col2im_add, im2col, matmul, matmul_nt, matmul_tn, set_thread_parallelism_cap, simd_available,
    transpose, with_backend, Backend, BitPlaneMatrix, ConvGeom,
};
use bsq::tensor::Tensor;
use bsq::util::Pcg32;

fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f64; m * n];
    for i in 0..m {
        for kk in 0..k {
            let aik = a[i * k + kk] as f64;
            for j in 0..n {
                c[i * n + j] += aik * b[kk * n + j] as f64;
            }
        }
    }
    c.into_iter().map(|v| v as f32).collect()
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let scale = g.abs().max(w.abs()).max(1.0);
        assert!((g - w).abs() <= tol * scale, "{what}[{i}]: {g} vs {w}");
    }
}

#[test]
fn dense_gemm_matches_naive_across_random_shapes() {
    let mut rng = Pcg32::seeded(11);
    for case in 0..40 {
        let m = 1 + rng.below(40) as usize;
        let k = 1 + rng.below(200) as usize;
        let n = 1 + rng.below(90) as usize;
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let want = naive(&a, &b, m, k, n);
        assert_close(&matmul(&a, &b, m, k, n), &want, 1e-4, &format!("case {case}"));
        assert_close(
            &matmul_tn(&transpose(&a, m, k), &b, k, m, n),
            &want,
            1e-4,
            &format!("tn case {case}"),
        );
        assert_close(
            &matmul_nt(&a, &transpose(&b, k, n), m, k, n),
            &want,
            1e-4,
            &format!("nt case {case}"),
        );
    }
}

fn random_codes(rng: &mut Pcg32, len: usize, bits: usize) -> Vec<i16> {
    let cap = (1u32 << bits) - 1;
    (0..len)
        .map(|_| {
            let mag = rng.below(cap + 1) as i16;
            if rng.bool(0.5) {
                mag
            } else {
                -mag
            }
        })
        .collect()
}

/// The issue's core differential claim: bit-plane GEMM ≡ naive f32 GEMM on
/// the dequantized weights, within 1e-4, over randomized shapes, random
/// sign patterns, word-boundary K (63/64/65) and every plane width.
#[test]
fn bitplane_gemm_matches_naive_reference() {
    let mut rng = Pcg32::seeded(12);
    let mut ks = vec![63usize, 64, 65];
    for _ in 0..9 {
        ks.push(1 + rng.below(190) as usize);
    }
    for (case, &k) in ks.iter().enumerate() {
        let m = 1 + rng.below(9) as usize;
        let n = 1 + rng.below(24) as usize;
        let bits = 1 + (case % 8);
        let codes = random_codes(&mut rng, k * n, bits);
        let delta = rng.range(0.001, 0.3);
        let bpm = BitPlaneMatrix::from_codes(&codes, k, n, bits, delta);
        let dense: Vec<f32> = codes.iter().map(|&c| c as f32 * delta).collect();
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let got_t = bpm.matmul_t(&transpose(&x, m, k), m);
        assert_close(
            &transpose(&got_t, n, m),
            &naive(&x, &dense, m, k, n),
            1e-4,
            &format!("k={k} bits={bits}"),
        );
    }
}

/// Sweep 0..=8 trimmed planes: values must keep matching the dense
/// reference, and the kernel's work metric (set bits) must shrink
/// monotonically toward zero.
#[test]
fn trim_sweep_keeps_exactness_and_shrinks_work() {
    let mut rng = Pcg32::seeded(13);
    let (m, k, n) = (6usize, 130usize, 12usize);
    let codes = random_codes(&mut rng, k * n, 8);
    let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let xt = transpose(&x, m, k);
    // sign-magnitude LSB drop (what drop_low_planes does): |c| >> t, sign kept
    let shr_mag = |c: i16, t: usize| -> i16 {
        let m = (c.unsigned_abs() >> t) as i16;
        if c < 0 {
            -m
        } else {
            m
        }
    };
    let mut last_nnz = u64::MAX;
    for t in 0..=8usize {
        let shifted: Vec<i16> = codes.iter().map(|&c| shr_mag(c, t)).collect();
        let delta = (1u32 << t) as f32 * 0.01;
        let bpm = BitPlaneMatrix::from_codes(&shifted, k, n, 8 - t, delta);
        assert!(bpm.nnz_bits() <= last_nnz, "t={t}: set bits grew");
        assert!(bpm.occupied_planes() <= 8 - t, "t={t}: too many live planes");
        last_nnz = bpm.nnz_bits();
        let dense: Vec<f32> = shifted.iter().map(|&c| c as f32 * delta).collect();
        let got = transpose(&bpm.matmul_t(&xt, m), n, m);
        assert_close(&got, &naive(&x, &dense, m, k, n), 1e-4, &format!("trim {t}"));
    }
    assert_eq!(last_nnz, 0, "8 trimmed planes must leave no work");
}

/// End-to-end bridge from the quant layer: a trained-then-requantized layer
/// packed via `quant::packed` multiplies identically to its dequantized
/// dense form.
#[test]
fn packed_layer_multiplies_like_its_dequantization() {
    let mut rng = Pcg32::seeded(14);
    for n_bits in [3usize, 6, 8] {
        let w = Tensor::randn(&[3, 3, 7, 9], 0.4, &mut rng);
        let mut rep = to_bitplanes(&w, n_bits).unwrap();
        // perturb into continuous mid-training planes, then requantize
        for v in rep.wp.data_mut().iter_mut().chain(rep.wn.data_mut()) {
            *v = (*v + rng.range(-0.3, 0.3)).clamp(0.0, 2.0);
        }
        requantize(&mut rep);
        let packed = rep.pack();
        let bpm = BitPlaneMatrix::from_packed(&packed);
        let dense = packed.dequantize();
        let (k, n) = (63usize, 9usize); // 3·3·7 = 63: word-boundary K
        let m = 5usize;
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let got = transpose(&bpm.matmul_t(&transpose(&x, m, k), m), n, m);
        assert_close(&got, &naive(&x, dense.data(), m, k, n), 1e-4, "packed bridge");
    }
}

// -- SIMD-vs-scalar differential + determinism ------------------------------

fn randv(rng: &mut Pcg32, len: usize) -> Vec<f32> {
    (0..len).map(|_| rng.normal()).collect()
}

/// Dense SIMD agrees with the scalar backend within FMA rounding (≤1e-4
/// relative, the documented tolerance) on all three layout variants, over
/// the adversarial K/N sizes and random shapes.
#[test]
fn simd_dense_matches_scalar_within_fma_tolerance() {
    if !simd_available() {
        return;
    }
    let mut rng = Pcg32::seeded(21);
    let mut cases =
        vec![(1usize, 1usize, 1usize), (3, 63, 65), (8, 64, 64), (5, 65, 1), (1, 63, 63)];
    for _ in 0..20 {
        cases.push((
            1 + rng.below(40) as usize,
            1 + rng.below(200) as usize,
            1 + rng.below(90) as usize,
        ));
    }
    for (case, &(m, k, n)) in cases.iter().enumerate() {
        let a = randv(&mut rng, m * k);
        let b = randv(&mut rng, k * n);
        let scalar = with_backend(Backend::Scalar, || matmul(&a, &b, m, k, n));
        let simd = with_backend(Backend::Avx2Fma, || matmul(&a, &b, m, k, n));
        assert_close(&simd, &scalar, 1e-4, &format!("simd nn case {case} ({m}x{k}x{n})"));
        let at = transpose(&a, m, k);
        let scalar_tn = with_backend(Backend::Scalar, || matmul_tn(&at, &b, k, m, n));
        let simd_tn = with_backend(Backend::Avx2Fma, || matmul_tn(&at, &b, k, m, n));
        assert_close(&simd_tn, &scalar_tn, 1e-4, &format!("simd tn case {case}"));
        let bt = transpose(&b, k, n);
        let scalar_nt = with_backend(Backend::Scalar, || matmul_nt(&a, &bt, m, k, n));
        let simd_nt = with_backend(Backend::Avx2Fma, || matmul_nt(&a, &bt, m, k, n));
        assert_close(&simd_nt, &scalar_nt, 1e-4, &format!("simd nt case {case}"));
    }
}

/// Remainder-tile sweep: every (m, n) residue of the 8×8 register block ×
/// K values straddling both the microkernel's KC boundary and the u64
/// word boundary. Each cell checks SIMD vs the f64 naive reference, so a
/// bad tail mask or mispacked edge tile cannot hide behind a matching-bug
/// scalar comparison.
#[test]
fn simd_remainder_tiles_cover_all_residues() {
    if !simd_available() {
        return;
    }
    let mut rng = Pcg32::seeded(22);
    for mm in [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 16, 17] {
        for nn in [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 15] {
            for kk in [1usize, 63, 64, 65, 255, 256, 257] {
                let a = randv(&mut rng, mm * kk);
                let b = randv(&mut rng, kk * nn);
                let got = with_backend(Backend::Avx2Fma, || matmul(&a, &b, mm, kk, nn));
                assert_close(
                    &got,
                    &naive(&a, &b, mm, kk, nn),
                    1e-4,
                    &format!("residue m={mm} k={kk} n={nn}"),
                );
            }
        }
    }
}

/// SIMD determinism: bitwise-identical results across repeats, any thread
/// cap, and emulated shard row-partitions (the per-sample dW split the
/// sharded trainer's bit-identity guarantee rides on).
#[test]
fn simd_results_are_bitwise_partition_invariant() {
    if !simd_available() {
        return;
    }
    let mut rng = Pcg32::seeded(23);
    // big enough to clear PAR_THRESHOLD so the caps actually change fan-out
    let (m, k, n) = (64usize, 256usize, 160usize);
    let a = randv(&mut rng, m * k);
    let b = randv(&mut rng, k * n);
    let reference = with_backend(Backend::Avx2Fma, || matmul(&a, &b, m, k, n));

    // repeats
    for rep in 0..3 {
        let again = with_backend(Backend::Avx2Fma, || matmul(&a, &b, m, k, n));
        assert_eq!(reference, again, "repeat {rep} moved bits");
    }
    // thread caps
    for cap in [1usize, 2, 3, 5, usize::MAX] {
        let capped = with_backend(Backend::Avx2Fma, || {
            set_thread_parallelism_cap(cap);
            let c = matmul(&a, &b, m, k, n);
            set_thread_parallelism_cap(usize::MAX);
            c
        });
        assert_eq!(reference, capped, "cap {cap} moved bits");
    }
    // emulated shard partitions: arbitrary (unaligned) row splits
    for splits in [vec![1usize, 63], vec![7, 25, 32], vec![17, 17, 17, 13]] {
        assert_eq!(splits.iter().sum::<usize>(), m);
        let mut stitched = Vec::with_capacity(m * n);
        let mut r0 = 0usize;
        for rows in splits {
            let sub = with_backend(Backend::Avx2Fma, || {
                matmul(&a[r0 * k..(r0 + rows) * k], &b, rows, k, n)
            });
            stitched.extend_from_slice(&sub);
            r0 += rows;
        }
        assert_eq!(reference, stitched, "row partition moved bits");
    }
}

/// The bit-plane SIMD kernel is bitwise equal to the scalar walk — not
/// merely close: serve logits must not move when dispatch flips, and the
/// batched result must contain each single-sample result exactly
/// (batcher coalescing invariance), including trimmed and empty planes.
#[test]
fn simd_bitplane_is_bitwise_scalar_and_batch_invariant() {
    if !simd_available() {
        return;
    }
    let mut rng = Pcg32::seeded(24);
    for &(k, n) in &[(63usize, 5usize), (64, 8), (65, 7), (130, 12), (1, 1)] {
        for bits in [1usize, 4, 8] {
            let codes = random_codes(&mut rng, k * n, bits);
            let bpm = BitPlaneMatrix::from_codes(&codes, k, n, bits, 0.037);
            for m in [1usize, 3, 7, 8, 9, 16] {
                let x = randv(&mut rng, m * k);
                let xt = transpose(&x, m, k);
                let scalar = with_backend(Backend::Scalar, || bpm.matmul_t(&xt, m));
                let simd = with_backend(Backend::Avx2Fma, || bpm.matmul_t(&xt, m));
                assert_eq!(scalar, simd, "bitplane k={k} n={n} bits={bits} m={m} moved bits");
                // batch invariance: column i of the [N, M] batched output
                // is exactly the single-sample product of sample i
                for i in 0..m {
                    let xti: Vec<f32> = (0..k).map(|kk| xt[kk * m + i]).collect();
                    let single = with_backend(Backend::Avx2Fma, || bpm.matmul_t(&xti, 1));
                    for j in 0..n {
                        assert_eq!(
                            simd[j * m + i],
                            single[j],
                            "batched sample {i} of {m} differs at output {j}"
                        );
                    }
                }
            }
        }
    }
    // fully-trimmed planes and the empty matrix stay exact zeros on SIMD
    let empty = BitPlaneMatrix::from_codes(&[0i16; 12], 4, 3, 8, 1.0);
    let out = with_backend(Backend::Avx2Fma, || empty.matmul_t(&[1.0f32; 8], 2));
    assert!(out.iter().all(|&v| v == 0.0));
}

fn naive_conv(x: &[f32], w: &[f32], g: &ConvGeom) -> Vec<f32> {
    let mut y = vec![0.0f64; g.rows() * g.cout];
    for ni in 0..g.n {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                for ky in 0..g.kh {
                    let iy = (oy * g.stride + ky) as isize - g.pad_top as isize;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = (ox * g.stride + kx) as isize - g.pad_left as isize;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        for ci in 0..g.cin {
                            let xv = x[((ni * g.h + iy as usize) * g.w + ix as usize) * g.cin + ci]
                                as f64;
                            for co in 0..g.cout {
                                let wv = w[((ky * g.kw + kx) * g.cin + ci) * g.cout + co] as f64;
                                y[((ni * g.oh + oy) * g.ow + ox) * g.cout + co] += xv * wv;
                            }
                        }
                    }
                }
            }
        }
    }
    y.into_iter().map(|v| v as f32).collect()
}

/// Property: im2col + GEMM is exactly a SAME-padded convolution, and
/// col2im is its adjoint (the identity conv backward depends on).
#[test]
fn im2col_roundtrip_properties() {
    let mut rng = Pcg32::seeded(15);
    for case in 0..12 {
        let n = 1 + rng.below(3) as usize;
        let h = 3 + rng.below(12) as usize;
        let w = 3 + rng.below(12) as usize;
        let cin = 1 + rng.below(5) as usize;
        let cout = 1 + rng.below(6) as usize;
        let stride = 1 + (case % 2);
        let g = ConvGeom::same(n, h, w, cin, 3, 3, cout, stride);
        let x: Vec<f32> = (0..n * h * w * cin).map(|_| rng.normal()).collect();
        let wmat: Vec<f32> = (0..g.kdim() * cout).map(|_| rng.normal()).collect();

        // conv equivalence
        let patches = im2col(&x, &g);
        let got = matmul(&patches, &wmat, g.rows(), g.kdim(), cout);
        assert_close(&got, &naive_conv(&x, &wmat, &g), 1e-4, &format!("conv case {case}"));

        // adjoint: <im2col(x), P> == <x, col2im(P)>
        let p: Vec<f32> = (0..g.rows() * g.kdim()).map(|_| rng.normal()).collect();
        let mut dx = vec![0.0f32; x.len()];
        col2im_add(&p, &g, &mut dx);
        let lhs: f64 = patches.iter().zip(&p).map(|(&a, &b)| (a * b) as f64).sum();
        let rhs: f64 = x.iter().zip(&dx).map(|(&a, &b)| (a * b) as f64).sum();
        assert!(
            (lhs - rhs).abs() <= 1e-3 * lhs.abs().max(1.0),
            "adjoint case {case}: {lhs} vs {rhs}"
        );
    }
}
