//! Differential tests for `tensor::gemm`: the blocked dense kernels, the
//! im2col convolution lowering and the bit-plane GEMM are all checked
//! against a naive f64 reference across randomized shapes, sign patterns,
//! word-boundary sizes and 0–8 trimmed planes.

use bsq::quant::{requantize, to_bitplanes};
use bsq::tensor::gemm::{
    col2im_add, im2col, matmul, matmul_nt, matmul_tn, transpose, BitPlaneMatrix, ConvGeom,
};
use bsq::tensor::Tensor;
use bsq::util::Pcg32;

fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut c = vec![0.0f64; m * n];
    for i in 0..m {
        for kk in 0..k {
            let aik = a[i * k + kk] as f64;
            for j in 0..n {
                c[i * n + j] += aik * b[kk * n + j] as f64;
            }
        }
    }
    c.into_iter().map(|v| v as f32).collect()
}

fn assert_close(got: &[f32], want: &[f32], tol: f32, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let scale = g.abs().max(w.abs()).max(1.0);
        assert!((g - w).abs() <= tol * scale, "{what}[{i}]: {g} vs {w}");
    }
}

#[test]
fn dense_gemm_matches_naive_across_random_shapes() {
    let mut rng = Pcg32::seeded(11);
    for case in 0..40 {
        let m = 1 + rng.below(40) as usize;
        let k = 1 + rng.below(200) as usize;
        let n = 1 + rng.below(90) as usize;
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let want = naive(&a, &b, m, k, n);
        assert_close(&matmul(&a, &b, m, k, n), &want, 1e-4, &format!("case {case}"));
        assert_close(
            &matmul_tn(&transpose(&a, m, k), &b, k, m, n),
            &want,
            1e-4,
            &format!("tn case {case}"),
        );
        assert_close(
            &matmul_nt(&a, &transpose(&b, k, n), m, k, n),
            &want,
            1e-4,
            &format!("nt case {case}"),
        );
    }
}

fn random_codes(rng: &mut Pcg32, len: usize, bits: usize) -> Vec<i16> {
    let cap = (1u32 << bits) - 1;
    (0..len)
        .map(|_| {
            let mag = rng.below(cap + 1) as i16;
            if rng.bool(0.5) {
                mag
            } else {
                -mag
            }
        })
        .collect()
}

/// The issue's core differential claim: bit-plane GEMM ≡ naive f32 GEMM on
/// the dequantized weights, within 1e-4, over randomized shapes, random
/// sign patterns, word-boundary K (63/64/65) and every plane width.
#[test]
fn bitplane_gemm_matches_naive_reference() {
    let mut rng = Pcg32::seeded(12);
    let mut ks = vec![63usize, 64, 65];
    for _ in 0..9 {
        ks.push(1 + rng.below(190) as usize);
    }
    for (case, &k) in ks.iter().enumerate() {
        let m = 1 + rng.below(9) as usize;
        let n = 1 + rng.below(24) as usize;
        let bits = 1 + (case % 8);
        let codes = random_codes(&mut rng, k * n, bits);
        let delta = rng.range(0.001, 0.3);
        let bpm = BitPlaneMatrix::from_codes(&codes, k, n, bits, delta);
        let dense: Vec<f32> = codes.iter().map(|&c| c as f32 * delta).collect();
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let got_t = bpm.matmul_t(&transpose(&x, m, k), m);
        assert_close(
            &transpose(&got_t, n, m),
            &naive(&x, &dense, m, k, n),
            1e-4,
            &format!("k={k} bits={bits}"),
        );
    }
}

/// Sweep 0..=8 trimmed planes: values must keep matching the dense
/// reference, and the kernel's work metric (set bits) must shrink
/// monotonically toward zero.
#[test]
fn trim_sweep_keeps_exactness_and_shrinks_work() {
    let mut rng = Pcg32::seeded(13);
    let (m, k, n) = (6usize, 130usize, 12usize);
    let codes = random_codes(&mut rng, k * n, 8);
    let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
    let xt = transpose(&x, m, k);
    // sign-magnitude LSB drop (what drop_low_planes does): |c| >> t, sign kept
    let shr_mag = |c: i16, t: usize| -> i16 {
        let m = (c.unsigned_abs() >> t) as i16;
        if c < 0 {
            -m
        } else {
            m
        }
    };
    let mut last_nnz = u64::MAX;
    for t in 0..=8usize {
        let shifted: Vec<i16> = codes.iter().map(|&c| shr_mag(c, t)).collect();
        let delta = (1u32 << t) as f32 * 0.01;
        let bpm = BitPlaneMatrix::from_codes(&shifted, k, n, 8 - t, delta);
        assert!(bpm.nnz_bits() <= last_nnz, "t={t}: set bits grew");
        assert!(bpm.occupied_planes() <= 8 - t, "t={t}: too many live planes");
        last_nnz = bpm.nnz_bits();
        let dense: Vec<f32> = shifted.iter().map(|&c| c as f32 * delta).collect();
        let got = transpose(&bpm.matmul_t(&xt, m), n, m);
        assert_close(&got, &naive(&x, &dense, m, k, n), 1e-4, &format!("trim {t}"));
    }
    assert_eq!(last_nnz, 0, "8 trimmed planes must leave no work");
}

/// End-to-end bridge from the quant layer: a trained-then-requantized layer
/// packed via `quant::packed` multiplies identically to its dequantized
/// dense form.
#[test]
fn packed_layer_multiplies_like_its_dequantization() {
    let mut rng = Pcg32::seeded(14);
    for n_bits in [3usize, 6, 8] {
        let w = Tensor::randn(&[3, 3, 7, 9], 0.4, &mut rng);
        let mut rep = to_bitplanes(&w, n_bits).unwrap();
        // perturb into continuous mid-training planes, then requantize
        for v in rep.wp.data_mut().iter_mut().chain(rep.wn.data_mut()) {
            *v = (*v + rng.range(-0.3, 0.3)).clamp(0.0, 2.0);
        }
        requantize(&mut rep);
        let packed = rep.pack();
        let bpm = BitPlaneMatrix::from_packed(&packed);
        let dense = packed.dequantize();
        let (k, n) = (63usize, 9usize); // 3·3·7 = 63: word-boundary K
        let m = 5usize;
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let got = transpose(&bpm.matmul_t(&transpose(&x, m, k), m), n, m);
        assert_close(&got, &naive(&x, dense.data(), m, k, n), 1e-4, "packed bridge");
    }
}

fn naive_conv(x: &[f32], w: &[f32], g: &ConvGeom) -> Vec<f32> {
    let mut y = vec![0.0f64; g.rows() * g.cout];
    for ni in 0..g.n {
        for oy in 0..g.oh {
            for ox in 0..g.ow {
                for ky in 0..g.kh {
                    let iy = (oy * g.stride + ky) as isize - g.pad_top as isize;
                    if iy < 0 || iy >= g.h as isize {
                        continue;
                    }
                    for kx in 0..g.kw {
                        let ix = (ox * g.stride + kx) as isize - g.pad_left as isize;
                        if ix < 0 || ix >= g.w as isize {
                            continue;
                        }
                        for ci in 0..g.cin {
                            let xv = x[((ni * g.h + iy as usize) * g.w + ix as usize) * g.cin + ci]
                                as f64;
                            for co in 0..g.cout {
                                let wv = w[((ky * g.kw + kx) * g.cin + ci) * g.cout + co] as f64;
                                y[((ni * g.oh + oy) * g.ow + ox) * g.cout + co] += xv * wv;
                            }
                        }
                    }
                }
            }
        }
    }
    y.into_iter().map(|v| v as f32).collect()
}

/// Property: im2col + GEMM is exactly a SAME-padded convolution, and
/// col2im is its adjoint (the identity conv backward depends on).
#[test]
fn im2col_roundtrip_properties() {
    let mut rng = Pcg32::seeded(15);
    for case in 0..12 {
        let n = 1 + rng.below(3) as usize;
        let h = 3 + rng.below(12) as usize;
        let w = 3 + rng.below(12) as usize;
        let cin = 1 + rng.below(5) as usize;
        let cout = 1 + rng.below(6) as usize;
        let stride = 1 + (case % 2);
        let g = ConvGeom::same(n, h, w, cin, 3, 3, cout, stride);
        let x: Vec<f32> = (0..n * h * w * cin).map(|_| rng.normal()).collect();
        let wmat: Vec<f32> = (0..g.kdim() * cout).map(|_| rng.normal()).collect();

        // conv equivalence
        let patches = im2col(&x, &g);
        let got = matmul(&patches, &wmat, g.rows(), g.kdim(), cout);
        assert_close(&got, &naive_conv(&x, &wmat, &g), 1e-4, &format!("conv case {case}"));

        // adjoint: <im2col(x), P> == <x, col2im(P)>
        let p: Vec<f32> = (0..g.rows() * g.kdim()).map(|_| rng.normal()).collect();
        let mut dx = vec![0.0f32; x.len()];
        col2im_add(&p, &g, &mut dx);
        let lhs: f64 = patches.iter().zip(&p).map(|(&a, &b)| (a * b) as f64).sum();
        let rhs: f64 = x.iter().zip(&dx).map(|(&a, &b)| (a * b) as f64).sum();
        assert!(
            (lhs - rhs).abs() <= 1e-3 * lhs.abs().max(1.0),
            "adjoint case {case}: {lhs} vs {rhs}"
        );
    }
}
