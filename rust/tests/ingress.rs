//! Integration tests for the HTTP ingress (DESIGN.md §15), driven over
//! real loopback sockets with a hand-rolled client:
//!
//! * logits served over the socket are bit-identical to the closed-loop
//!   pool path — through both the octet and the JSON body encodings;
//! * the parser's limits reject malformed, oversized, and unsupported
//!   requests with the mapped status codes, and pipelined requests are
//!   answered in order;
//! * a full queue sheds normal traffic `429 + Retry-After` while the
//!   priority lane's reserved headroom still admits high-priority work
//!   (batcher stalled deterministically via fault injection);
//! * per-tenant token-bucket quotas shed the over-quota tenant only, and
//!   refill on schedule;
//! * the connection bound answers `503` at accept time, and shutdown is
//!   clean with an idle keep-alive connection still open.
//!
//! Every test holds a `faults::inject` guard (empty schedule unless it
//! arms one) so the process-global fault plane never bleeds between
//! concurrently running tests.

use std::io::{BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::Duration;

use anyhow::Result;
use bsq::faults::{self, Schedule};
use bsq::runtime::Engine;
use bsq::serve::ingress::admission::{AdmissionCfg, QuotaCfg};
use bsq::serve::ingress::http::{self, Limits, Response};
use bsq::serve::{
    self, run_closed_loop, run_ingress, synthetic_input, BatchPolicy, IngressConfig, PoolConfig,
    Registry, RouteSource, RouteSpec,
};
use bsq::util::json;

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bsq_ingress_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn tiny_route(engine: &Engine, dir: &std::path::Path, seed: u64) -> RouteSpec {
    let ckpt = dir.join(format!("tiny_s{seed}.ckpt"));
    if !ckpt.exists() {
        serve::synthesize_quantized_checkpoint(engine, "tinynet", 6, seed, &ckpt).unwrap();
    }
    RouteSpec {
        model: "tinynet".to_string(),
        source: RouteSource::Checkpoint(ckpt),
        act_bits: 4,
        act_first_last: 8,
    }
}

/// Raw test client: writes requests by hand, parses responses with the
/// crate's own client-side parser. Long read timeout — some tests hold
/// requests in a deliberately stalled queue.
struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    limits: Limits,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        stream.set_nodelay(true).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        let limits = Limits { read_timeout: Duration::from_secs(20), ..Limits::default() };
        Client { reader, writer: stream, limits }
    }

    fn send_raw(&mut self, bytes: &[u8]) {
        self.writer.write_all(bytes).unwrap();
        self.writer.flush().unwrap();
    }

    fn recv(&mut self) -> Response {
        http::read_response(&mut self.reader, &self.limits).unwrap()
    }

    fn get(&mut self, path: &str) -> Response {
        self.send_raw(format!("GET {path} HTTP/1.1\r\nhost: t\r\n\r\n").as_bytes());
        self.recv()
    }

    /// Write a POST infer without waiting for the response (tests that
    /// park requests in the queue read the response later).
    fn post_infer_async(&mut self, model: &str, body: &[u8], extra: &[(&str, &str)]) {
        let mut head = format!(
            "POST /v1/models/{model}/infer HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\n",
            body.len()
        );
        for (k, v) in extra {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str("\r\n");
        let mut wire = head.into_bytes();
        wire.extend_from_slice(body);
        self.send_raw(&wire);
    }

    fn post_infer(&mut self, model: &str, body: &[u8], extra: &[(&str, &str)]) -> Response {
        self.post_infer_async(model, body, extra);
        self.recv()
    }
}

fn octet_body(x: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(x.len() * 4);
    for v in x {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn le_f32s(bytes: &[u8]) -> Vec<f32> {
    bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect()
}

fn queue_depth(addr: SocketAddr) -> usize {
    let mut c = Client::connect(addr);
    let r = c.get("/v1/models");
    assert_eq!(r.status, 200);
    let v = json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
    v.as_arr().unwrap()[0].get("queue_depth").unwrap().as_usize().unwrap()
}

#[test]
fn socket_logits_are_bit_identical_to_the_closed_loop_path() -> Result<()> {
    let _g = faults::inject(Schedule::default());
    let engine = Engine::native();
    let dir = scratch("ident");
    let route = tiny_route(&engine, &dir, 3);
    let RouteSource::Checkpoint(ckpt) = &route.source else { unreachable!() };

    // Reference: the same synthetic inputs through the in-process
    // closed-loop pool (whose batch-composition independence serve_e2e
    // already pins down).
    let registry = Registry::new(&engine);
    let sv = registry.load("tinynet", ckpt, 4, 8).unwrap();
    let elems = sv.sample_elems();
    let pool_cfg = PoolConfig::new(2, BatchPolicy::new(4, Duration::from_millis(2)));
    let (_stats, reference) = run_closed_loop(sv.as_ref(), &pool_cfg, 6, 1, 77).unwrap();

    let (report, ()) =
        run_ingress(&engine, &[route], &pool_cfg, &IngressConfig::default(), |h| {
            let mut c = Client::connect(h.addr());

            let r = c.get("/healthz");
            assert_eq!(r.status, 200);

            let r = c.get("/v1/models");
            assert_eq!(r.status, 200);
            let v = json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
            let m = &v.as_arr().unwrap()[0];
            assert_eq!(m.get("model").unwrap().as_str().unwrap(), "tinynet");
            assert_eq!(m.get("sample_elems").unwrap().as_usize().unwrap(), elems);
            assert_eq!(
                m.get("weights_digest").unwrap().as_str().unwrap(),
                sv.weights_digest.as_str()
            );

            for resp in &reference {
                let x = synthetic_input(77, resp.client, resp.index, elems);

                // Octet in, octet out: raw little-endian f32 both ways.
                let r = c.post_infer(
                    "tinynet",
                    &octet_body(&x),
                    &[
                        ("content-type", "application/octet-stream"),
                        ("accept", "application/octet-stream"),
                    ],
                );
                assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
                let got = le_f32s(&r.body);
                assert_eq!(got.len(), resp.logits.len());
                for (a, b) in got.iter().zip(&resp.logits) {
                    assert_eq!(a.to_bits(), b.to_bits(), "octet logits drifted");
                }
                assert_eq!(
                    r.header_value("x-bsq-argmax").unwrap(),
                    resp.argmax.to_string()
                );

                // JSON in, JSON out: f32→f64 printing is shortest
                // round-trip exact in both directions, so even the text
                // encoding must preserve every logit bit.
                let jbody = format!(
                    "{{\"x\":[{}]}}",
                    x.iter().map(|v| format!("{}", *v as f64)).collect::<Vec<_>>().join(",")
                );
                let r = c.post_infer(
                    "tinynet",
                    jbody.as_bytes(),
                    &[("content-type", "application/json")],
                );
                assert_eq!(r.status, 200, "{}", String::from_utf8_lossy(&r.body));
                let v = json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
                assert_eq!(v.get("argmax").unwrap().as_usize().unwrap(), resp.argmax);
                let logits = v.get("logits").unwrap().as_arr().unwrap();
                assert_eq!(logits.len(), resp.logits.len());
                for (j, b) in logits.iter().zip(&resp.logits) {
                    assert_eq!(
                        (j.as_f64().unwrap() as f32).to_bits(),
                        b.to_bits(),
                        "json logits drifted"
                    );
                }
            }
        })?;

    assert_eq!(report.served as usize, 2 + 2 * reference.len());
    assert_eq!(report.rejected, 0);
    assert_eq!(report.failed, 0);
    assert_eq!(report.shed_queue + report.shed_quota, 0);
    assert_eq!(report.routes[0].worker_panics, 0);
    assert!(report.routes[0].batches > 0);
    std::fs::remove_dir_all(dir).ok();
    Ok(())
}

#[test]
fn malformed_and_unsupported_requests_map_to_their_statuses() -> Result<()> {
    let _g = faults::inject(Schedule::default());
    let engine = Engine::native();
    let dir = scratch("reject");
    let route = tiny_route(&engine, &dir, 4);

    let pool_cfg = PoolConfig::new(1, BatchPolicy::new(4, Duration::from_millis(1)));
    let (report, ()) =
        run_ingress(&engine, &[route], &pool_cfg, &IngressConfig::default(), |h| {
            let addr = h.addr();
            // Framing errors answer on a fresh connection each (the server
            // closes after any of them — stream position is unreliable).
            let expect_close = |raw: &[u8], status: u16, tag: &str| {
                let mut c = Client::connect(addr);
                c.send_raw(raw);
                let r = c.recv();
                assert_eq!(r.status, status, "{tag}: {}", String::from_utf8_lossy(&r.body));
                r
            };

            expect_close(b"GARBAGE\r\n\r\n", 400, "bad request line");
            expect_close(b"GET /healthz HTTP/2.0\r\n\r\n", 400, "bad version");
            expect_close(b"GET /healthz HTTP/1.1\r\nno-colon\r\n\r\n", 400, "bad header");
            let r = expect_close(b"DELETE /healthz HTTP/1.1\r\n\r\n", 405, "bad method");
            assert_eq!(r.header_value("allow"), Some("GET, POST"));

            let long = format!("GET /healthz HTTP/1.1\r\nx-big: {}\r\n\r\n", "a".repeat(9000));
            expect_close(long.as_bytes(), 431, "oversized header line");

            let mut many = String::from("GET /healthz HTTP/1.1\r\n");
            for i in 0..80 {
                many.push_str(&format!("x-h{i}: v\r\n"));
            }
            many.push_str("\r\n");
            expect_close(many.as_bytes(), 431, "too many headers");

            expect_close(
                format!(
                    "POST /v1/models/tinynet/infer HTTP/1.1\r\ncontent-length: {}\r\n\r\n",
                    2 << 20
                )
                .as_bytes(),
                413,
                "oversized body",
            );

            // Routing/validation errors keep the connection alive.
            let mut c = Client::connect(addr);
            assert_eq!(c.get("/nope").status, 404);
            assert_eq!(c.post_infer("ghost", b"\0\0\0\0", &[]).status, 404);
            assert_eq!(c.get("/v1/models/tinynet/infer").status, 405);
            assert_eq!(c.post_infer("tinynet", b"abc", &[]).status, 400); // len % 4 != 0
            assert_eq!(c.post_infer("tinynet", b"\0\0\0\0", &[]).status, 400); // wrong shape
            assert_eq!(
                c.post_infer("tinynet", b"\0\0\0\0", &[("x-bsq-tenant", "bad tenant")]).status,
                400
            );
            assert_eq!(
                c.post_infer("tinynet", b"\0\0\0\0", &[("x-bsq-priority", "urgent")]).status,
                400
            );

            // Pipelined requests: three healthz in one write, three
            // responses in order on the same connection.
            c.send_raw(
                b"GET /healthz HTTP/1.1\r\n\r\nGET /healthz HTTP/1.1\r\n\r\nGET /healthz HTTP/1.1\r\n\r\n",
            );
            for i in 0..3 {
                let r = c.recv();
                assert_eq!(r.status, 200, "pipelined response {i}");
            }
        })?;

    assert_eq!(report.failed, 0);
    assert_eq!(report.shed_queue + report.shed_quota, 0);
    assert!(report.rejected >= 13, "rejected = {}", report.rejected);
    std::fs::remove_dir_all(dir).ok();
    Ok(())
}

#[test]
fn full_queue_sheds_normal_traffic_but_priority_lane_admits_high() -> Result<()> {
    // Stall the batcher's first batch for 2.5s: the queue backs up
    // deterministically while we probe the admission lanes.
    let _g = faults::inject(Schedule::parse("serve.batcher@0:delay=2500").unwrap());
    let engine = Engine::native();
    let dir = scratch("shed");
    let route = tiny_route(&engine, &dir, 5);
    let elems = {
        let registry = Registry::new(&engine);
        let RouteSource::Checkpoint(ckpt) = &route.source else { unreachable!() };
        registry.load("tinynet", ckpt, 4, 8).unwrap().sample_elems()
    };

    // workers=1, max_batch=1 → queue capacity 4; reserve_frac 0.25
    // reserves ceil(1) slot: normal lane closes at depth 3, high at 4.
    let pool_cfg = PoolConfig::new(1, BatchPolicy::new(1, Duration::from_millis(1)));
    let cfg = IngressConfig {
        admission: AdmissionCfg { reserve_frac: 0.25, ..Default::default() },
        ..Default::default()
    };
    let body = octet_body(&synthetic_input(9, 0, 0, elems));

    let (report, ()) = run_ingress(&engine, &[route], &pool_cfg, &cfg, |h| {
        let addr = h.addr();
        // Three normal requests parked in the stalled queue (responses
        // read later; their conn threads block on the reply channel).
        let mut parked: Vec<Client> = (0..3)
            .map(|i| {
                let mut c = Client::connect(addr);
                c.post_infer_async("tinynet", &body, &[("x-bsq-tenant", "filler")]);
                // Wait for this request to occupy the queue before the
                // next one, so depth is deterministic at every step.
                let want = i + 1;
                for _ in 0..500 {
                    if queue_depth(addr) >= want {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                assert!(queue_depth(addr) >= want, "request {i} never hit the queue");
                c
            })
            .collect();

        // Depth 3: the normal lane is closed…
        let mut c = Client::connect(addr);
        let r = c.post_infer("tinynet", &body, &[("x-bsq-tenant", "latecomer")]);
        assert_eq!(r.status, 429, "{}", String::from_utf8_lossy(&r.body));
        assert_eq!(r.header_value("x-bsq-shed"), Some("queue"));
        let coarse: u64 = r.header_value("retry-after").unwrap().parse().unwrap();
        assert!(coarse >= 1);
        let ms: u64 = r.header_value("x-bsq-retry-after-ms").unwrap().parse().unwrap();
        assert_eq!(ms, 250, "default retry hint");

        // …but the reserved slot still admits high-priority traffic.
        let mut high = Client::connect(addr);
        high.post_infer_async(
            "tinynet",
            &body,
            &[("x-bsq-tenant", "vip"), ("x-bsq-priority", "high")],
        );
        parked.push(high);

        // Once the stall clears, every admitted request is served.
        for (i, c) in parked.iter_mut().enumerate() {
            let r = c.recv();
            assert_eq!(r.status, 200, "parked request {i}");
        }
    })?;

    assert_eq!(report.shed_queue, 1);
    assert_eq!(report.shed_quota, 0);
    assert_eq!(report.failed, 0);
    assert_eq!(report.routes[0].worker_panics, 0);
    std::fs::remove_dir_all(dir).ok();
    Ok(())
}

#[test]
fn per_tenant_quota_sheds_the_noisy_tenant_only_and_refills() -> Result<()> {
    let _g = faults::inject(Schedule::default());
    let engine = Engine::native();
    let dir = scratch("quota");
    let route = tiny_route(&engine, &dir, 6);
    let elems = {
        let registry = Registry::new(&engine);
        let RouteSource::Checkpoint(ckpt) = &route.source else { unreachable!() };
        registry.load("tinynet", ckpt, 4, 8).unwrap().sample_elems()
    };

    let pool_cfg = PoolConfig::new(1, BatchPolicy::new(4, Duration::from_millis(1)));
    let cfg = IngressConfig {
        admission: AdmissionCfg {
            quota: Some(QuotaCfg { rate_per_sec: 2.0, burst: 2.0 }),
            ..Default::default()
        },
        ..Default::default()
    };
    let body = octet_body(&synthetic_input(11, 0, 0, elems));

    let (report, ()) = run_ingress(&engine, &[route], &pool_cfg, &cfg, |h| {
        let mut c = Client::connect(h.addr());
        let a = [("x-bsq-tenant", "team-a")];
        let b = [("x-bsq-tenant", "team-b")];

        // Burst of 2 admits, the third sheds with a refill-sized hint.
        assert_eq!(c.post_infer("tinynet", &body, &a).status, 200);
        assert_eq!(c.post_infer("tinynet", &body, &a).status, 200);
        let r = c.post_infer("tinynet", &body, &a);
        assert_eq!(r.status, 429, "{}", String::from_utf8_lossy(&r.body));
        assert_eq!(r.header_value("x-bsq-shed"), Some("quota"));
        let ms: u64 = r.header_value("x-bsq-retry-after-ms").unwrap().parse().unwrap();
        assert!(ms > 200 && ms <= 500, "refill hint {ms}ms at 2 tokens/s");

        // The other tenant's bucket is untouched.
        assert_eq!(c.post_infer("tinynet", &body, &b).status, 200);

        // After the hinted wait the bucket has refilled one token.
        std::thread::sleep(Duration::from_millis(ms + 100));
        assert_eq!(c.post_infer("tinynet", &body, &a).status, 200);
    })?;

    assert_eq!(report.served, 4);
    assert_eq!(report.shed_quota, 1);
    assert_eq!(report.shed_queue, 0);
    assert_eq!(report.failed, 0);
    std::fs::remove_dir_all(dir).ok();
    Ok(())
}

#[test]
fn connection_bound_answers_503_and_shutdown_survives_idle_conns() -> Result<()> {
    let _g = faults::inject(Schedule::default());
    let engine = Engine::native();
    let dir = scratch("conns");
    let route = tiny_route(&engine, &dir, 7);

    let pool_cfg = PoolConfig::new(1, BatchPolicy::new(4, Duration::from_millis(1)));
    let cfg = IngressConfig {
        max_conns: 1,
        // Short idle timeout so the shutdown flag is noticed quickly by
        // the idle keep-alive connection we abandon below.
        limits: Limits { read_timeout: Duration::from_millis(100), ..Limits::default() },
        ..Default::default()
    };
    let (report, ()) = run_ingress(&engine, &[route], &pool_cfg, &cfg, |h| {
        // First connection occupies the only slot…
        let mut held = Client::connect(h.addr());
        assert_eq!(held.get("/healthz").status, 200);
        // …so the second is rejected at accept time.
        let mut c = Client::connect(h.addr());
        let r = c.recv();
        assert_eq!(r.status, 503);
        assert_eq!(r.header_value("retry-after"), Some("1"));
        // Leave `held` open and idle: run_ingress must still return.
    })?;

    assert_eq!(report.conns, 1);
    assert_eq!(report.conns_rejected, 1);
    std::fs::remove_dir_all(dir).ok();
    Ok(())
}
