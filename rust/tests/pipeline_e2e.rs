//! Integration: the full BSQ pipeline + baselines on tinynet.
//!
//! Requires `make artifacts` (skips gracefully otherwise).

use bsq::baselines::{self, HawqConfig, QatConfig};
use bsq::coordinator::{run_bsq, BsqConfig, Session};
use bsq::model::ModelState;
use bsq::quant::{QuantScheme, Reweigh};
use bsq::runtime::Engine;

fn have_artifacts() -> bool {
    bsq::runtime::artifacts_root().join("tinynet/manifest.json").exists()
}

fn tiny_cfg() -> BsqConfig {
    let mut cfg = BsqConfig::for_model("tinynet");
    cfg.pretrain_epochs = 3;
    cfg.bsq_epochs = 4;
    cfg.finetune_epochs = 2;
    cfg.requant_interval = 2;
    cfg.train_size = 256;
    cfg.test_size = 128;
    cfg.alpha = 2.3e-4;
    cfg.cache_pretrained = false;
    cfg
}

#[test]
fn full_bsq_pipeline_compresses_and_learns() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let outcome = run_bsq(&engine, &tiny_cfg()).unwrap();

    // The pipeline must actually reduce precision below the 8-bit init…
    assert!(
        outcome.bits_per_param < 8.0,
        "no compression: {} bits/param",
        outcome.bits_per_param
    );
    assert!(outcome.compression > 4.0);
    // …while staying a valid scheme and a working model.
    assert_eq!(outcome.scheme.layers.len(), 4);
    assert!(outcome.scheme.layers.iter().all(|l| l.bits <= 9));
    assert!(outcome.acc_after_ft > 0.15, "model collapsed: {}", outcome.acc_after_ft);
    // history covers all three phases
    for phase in ["pretrain", "bsq", "finetune"] {
        assert!(outcome.history.last_of(phase).is_some(), "missing {phase}");
    }
}

#[test]
fn stronger_alpha_compresses_more() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let mut weak = tiny_cfg();
    weak.alpha = 2e-5;
    let mut strong = tiny_cfg();
    strong.alpha = 1e-3;
    let w = run_bsq(&engine, &weak).unwrap();
    let s = run_bsq(&engine, &strong).unwrap();
    // Allow half a bit of run-to-run noise at these abbreviated schedules;
    // the 50× α gap must still show a clear compression gap.
    assert!(
        s.bits_per_param <= w.bits_per_param + 0.5,
        "alpha monotonicity violated: {} (α=1e-3) vs {} (α=2e-5)",
        s.bits_per_param,
        w.bits_per_param
    );
}

#[test]
fn dorefa_from_scratch_trains() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let session = Session::open(&engine, "tinynet", 256, 128, 0).unwrap();
    let names: Vec<(String, usize)> =
        session.man.qlayers.iter().map(|q| (q.name.clone(), q.params)).collect();
    let scheme = QuantScheme::uniform(&names, 3);
    let out =
        baselines::dorefa::train_from_scratch(&session, &scheme, &QatConfig::from_scratch(4, 4, 0))
            .unwrap();
    assert!(out.final_acc > 0.15, "dorefa collapsed: {}", out.final_acc);
}

#[test]
fn hawq_analysis_ranks_layers() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let session = Session::open(&engine, "tinynet", 128, 64, 0).unwrap();
    let state = ModelState::init_fp(&session.man, 3);
    let report = baselines::hawq::analyze(
        &session,
        &state,
        &HawqConfig { power_iters: 4, batches: 1, seed: 1 },
    )
    .unwrap();
    assert_eq!(report.eigenvalues.len(), 4);
    assert!(report.eigenvalues.iter().all(|l| l.is_finite() && *l >= 0.0));
    // ranking is a permutation
    let mut r = report.ranking.clone();
    r.sort();
    assert_eq!(r, vec![0, 1, 2, 3]);

    // scheme assignment hits a reasonable budget
    let scheme = baselines::hawq::assign_scheme(&session, &report, 4.0, &[8, 4, 2]);
    assert!(scheme.bits_per_param() > 1.0 && scheme.bits_per_param() < 9.0);
}

#[test]
fn reweigh_ablation_changes_scheme() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::cpu().unwrap();
    let mut a = tiny_cfg();
    a.reweigh = Reweigh::MemoryAware;
    a.alpha = 2.3e-4;
    let mut b = tiny_cfg();
    b.reweigh = Reweigh::None;
    b.alpha = 9e-5; // paper pairs strengths for comparable compression
    let oa = run_bsq(&engine, &a).unwrap();
    let ob = run_bsq(&engine, &b).unwrap();
    assert_ne!(oa.scheme.bits_vec(), ob.scheme.bits_vec());
}
