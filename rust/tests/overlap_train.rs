//! Overlap-identity suite for the double-buffered re-quantization and the
//! background batch prefetcher (DESIGN.md §16).
//!
//! The contract under test: overlapping the requant rebuild against the
//! epoch-end eval window and moving batch assembly onto a prefetch thread
//! are pure wall-clock optimizations — the full `run_bsq` trajectory
//! (per-epoch loss/bgl/acc/eval-acc/bits) is **bit-identical** to the
//! pause-the-world, synchronous-loader ordering at every knob setting.

use bsq::coordinator::{
    requantize_overlapped, run_bsq, BsqConfig, BsqOutcome, RequantBuffers, Session,
};
use bsq::model::{momentum_slots, ModelState};
use bsq::runtime::{Engine, RunInputs};

fn tiny_cfg() -> BsqConfig {
    let mut cfg = BsqConfig::for_model("tinynet");
    cfg.pretrain_epochs = 1;
    cfg.bsq_epochs = 3;
    cfg.finetune_epochs = 1;
    cfg.requant_interval = 1;
    cfg.train_size = 96;
    cfg.test_size = 48;
    cfg.eval_batches = 2;
    cfg.alpha = 1e-4;
    cfg.cache_pretrained = false; // a cached fp checkpoint would mask drift
    // pin the knobs under test — the env-derived defaults would let the
    // CI leg's BSQ_SYNC_REQUANT/BSQ_PREFETCH_DEPTH leak into both runs
    cfg.sync_requant = true;
    cfg.prefetch_depth = 0;
    cfg
}

fn assert_outcomes_identical(a: &BsqOutcome, b: &BsqOutcome, ctx: &str) {
    assert_eq!(a.scheme.bits_vec(), b.scheme.bits_vec(), "{ctx}: scheme");
    assert_eq!(a.acc_before_ft.to_bits(), b.acc_before_ft.to_bits(), "{ctx}: acc_before_ft");
    assert_eq!(a.acc_after_ft.to_bits(), b.acc_after_ft.to_bits(), "{ctx}: acc_after_ft");
    assert_eq!(a.history.records.len(), b.history.records.len(), "{ctx}: record count");
    for (ra, rb) in a.history.records.iter().zip(&b.history.records) {
        let at = format!("{ctx} [{} epoch {}]", ra.phase, ra.epoch);
        assert_eq!(ra.phase, rb.phase, "{at}");
        assert_eq!(ra.epoch, rb.epoch, "{at}");
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "{at} loss");
        assert_eq!(ra.bgl.to_bits(), rb.bgl.to_bits(), "{at} bgl");
        assert_eq!(ra.acc.to_bits(), rb.acc.to_bits(), "{at} acc");
        assert_eq!(
            ra.eval_acc.map(f32::to_bits),
            rb.eval_acc.map(f32::to_bits),
            "{at} eval_acc"
        );
        assert_eq!(ra.bits_per_param.to_bits(), rb.bits_per_param.to_bits(), "{at} bits/param");
    }
}

/// The headline contract: a full pipeline with overlapped requant AND the
/// prefetcher reproduces the pause-the-world synchronous run bitwise.
#[test]
fn overlapped_run_matches_pause_the_world_bitwise() {
    let engine = Engine::native();
    let sync = run_bsq(&engine, &tiny_cfg()).unwrap();

    let mut cfg = tiny_cfg();
    cfg.sync_requant = false;
    cfg.prefetch_depth = 2;
    let overlapped = run_bsq(&engine, &cfg).unwrap();
    assert_outcomes_identical(&sync, &overlapped, "overlap+prefetch vs sync");
}

/// The prefetch depth is a pure buffering knob: any depth, same bits.
#[test]
fn prefetch_depth_is_trajectory_invariant() {
    let engine = Engine::native();
    let mut cfg = tiny_cfg();
    cfg.prefetch_depth = 1;
    let d1 = run_bsq(&engine, &cfg).unwrap();
    cfg.prefetch_depth = 4;
    let d4 = run_bsq(&engine, &cfg).unwrap();
    assert_outcomes_identical(&d1, &d4, "depth 1 vs 4");
}

/// Module-level: one requant boundary (rebuild + eval window + install)
/// leaves the state bitwise identical in both modes, returns the same
/// window value and the same adjust reports, and zeroes the plane momenta.
#[test]
fn one_boundary_is_state_identical_across_modes() {
    let engine = Engine::native();
    let session = Session::open(&engine, "tinynet", 96, 48, 0).unwrap();
    let exe = session.artifact("bsq_train_relu6").unwrap();
    let eval = session.artifact("q_eval_relu6").unwrap();
    let actlv = session.act_levels(4, 8);
    let eval_inputs = RunInputs::default().vec("actlv", actlv);

    let mut states = Vec::new();
    let mut evals = Vec::new();
    let mut reports = Vec::new();
    for sync in [true, false] {
        let mut state = ModelState::init_fp(&session.man, 7);
        state.to_bit_representation(&session.man, 8).unwrap();
        state.ensure_momenta(&momentum_slots(&exe.spec.inputs));
        // dirty the momenta so the install-time zeroing is observable
        for key in session.man.qlayers.iter().map(|q| format!("m:wp:{}", q.name)) {
            state.get_mut(&key).unwrap().data_mut().fill(0.25);
        }
        let (win, reps) = requantize_overlapped(
            &session,
            &mut state,
            &mut RequantBuffers::new(),
            sync,
            |st| session.evaluate(&eval, st, &eval_inputs, 2),
        )
        .unwrap();
        evals.push(win);
        reports.push(reps);
        states.push(state);
    }

    assert_eq!(evals[0].0.to_bits(), evals[1].0.to_bits(), "window loss");
    assert_eq!(evals[0].1.to_bits(), evals[1].1.to_bits(), "window acc");
    assert_eq!(reports[0], reports[1], "adjust reports");
    let keys: Vec<String> = states[0].keys().cloned().collect();
    assert_eq!(keys, states[1].keys().cloned().collect::<Vec<_>>());
    for key in &keys {
        assert_eq!(
            states[0].get(key).unwrap().data(),
            states[1].get(key).unwrap().data(),
            "{key} diverged across modes"
        );
    }
    for q in &session.man.qlayers {
        let m = states[1].get(&format!("m:wp:{}", q.name)).unwrap();
        assert!(m.data().iter().all(|&v| v == 0.0), "{}: momentum not zeroed", q.name);
    }
}
