//! Property suite for the layer-graph IR (`ir::{graph, plan, exec}`),
//! seeded like `tests/prop_quant.rs` — fixed seeds, so two consecutive
//! `cargo test` runs produce identical results.
//!
//! Three properties over **all four zoo models × their entry modes**:
//!
//! 1. **No aliasing** — the liveness-based arena plan never assigns two
//!    simultaneously-live activations overlapping ranges, in either plan
//!    mode.
//! 2. **Deterministic compilation** — compiling the same `(model, mode)`
//!    twice yields the same plan, bit for bit (offsets, schedule, fusion,
//!    scratch spec).
//! 3. **Executor bit-identity** — the fused, memory-reusing arena executor
//!    produces logits/loss bit-identical to the tape executor, which is
//!    the direct descendant of the pre-IR per-pass `Fwd` walk (same
//!    kernels, same evaluation order — the golden contract carried
//!    forward from before the shim's deletion), across fp / bit-plane /
//!    DoReFa weights and ReLU6 / PACT activations, including a
//!    stale-arena rerun and a fully-trimmed (elided) layer.

use std::collections::BTreeMap;

use bsq::ir::{self, PlanMode};
use bsq::model::ModelState;
use bsq::runtime::native::manifest_for;
use bsq::runtime::native::models;
use bsq::runtime::native::step::{eval_weights, AMode, WMode};
use bsq::tensor::Tensor;
use bsq::util::Pcg32;

fn random_input(rng: &mut Pcg32, m: usize, hw: (usize, usize), c: usize) -> Tensor {
    let n = m * hw.0 * hw.1 * c;
    Tensor::new(vec![m, hw.0, hw.1, c], (0..n).map(|_| rng.normal()).collect()).unwrap()
}

/// (1) Two buffers live at the same schedule step never share bytes.
#[test]
fn arena_plan_never_aliases_live_buffers() {
    for name in models::model_names() {
        let model = models::get(name).unwrap();
        for mode in [PlanMode::Train, PlanMode::Infer] {
            let p = ir::compile(&model, mode).unwrap();
            let n = p.graph.nodes.len();
            let mut checked = 0usize;
            for i in 0..n {
                for j in i + 1..n {
                    if j > p.last_use[i] {
                        continue; // i already retired when j is defined
                    }
                    let (ai, bi) = (p.offsets[i], p.offsets[i] + p.graph.nodes[i].elems());
                    let (aj, bj) = (p.offsets[j], p.offsets[j] + p.graph.nodes[j].elems());
                    assert!(
                        bi <= aj || bj <= ai,
                        "{name}/{mode:?}: live nodes {i} [{ai},{bi}) and {j} [{aj},{bj}) alias"
                    );
                    checked += 1;
                }
            }
            assert!(checked > 0, "{name}/{mode:?}: no live pairs checked");
            // the plan must also fit its own high-water mark
            for i in 0..n {
                assert!(p.offsets[i] + p.graph.nodes[i].elems() <= p.arena_elems);
            }
        }
    }
}

/// (2) Same `(model, mode)` → same plan, bit for bit.
#[test]
fn plan_compilation_is_deterministic() {
    for name in models::model_names() {
        let model = models::get(name).unwrap();
        for mode in [PlanMode::Train, PlanMode::Infer] {
            let a = ir::compile(&model, mode).unwrap();
            let b = ir::compile(&model, mode).unwrap();
            assert_eq!(a, b, "{name}/{mode:?} compiled differently twice");
        }
        // and the infer plan actually plans: reuse below naive, fusion > 0
        let infer = ir::compile(&model, PlanMode::Infer).unwrap();
        assert!(infer.fused > 0, "{name}: no conv-bn-act fused");
        assert!(infer.arena_elems < infer.naive_elems, "{name}: no arena savings");
    }
}

/// One executor-equivalence case: arena logits ≡ tape logits, bitwise.
fn assert_planned_matches_tape(
    name: &str,
    state: &ModelState,
    wm: WMode,
    am: AMode,
    wlv: Option<Vec<f32>>,
    bitplane: bool,
    seed: u64,
) -> usize {
    let model = models::get(name).unwrap();
    let plans = ir::plans_for(name).unwrap();
    let mut rng = Pcg32::seeded(seed);
    let actlv = vec![15.0f32; model.act_sites.len()];
    let m = 3usize; // deliberately not the manifest batch: plans are batch-free
    let x = random_input(&mut rng, m, model.input_hw, model.in_ch);

    let reps = eval_weights(&model, state, wm, wlv.as_deref(), bitplane).unwrap();
    let golden = ir::tape_logits(&model, state, reps, &actlv, am, x.clone()).unwrap();

    let reps = eval_weights(&model, state, wm, wlv.as_deref(), bitplane).unwrap();
    let bound = ir::bind(&plans.infer, &model, state, reps, &actlv, am).unwrap();
    let mut arena = ir::Arena::default();
    for round in 0..2 {
        // round 1 reruns on the dirty arena: stale values must not leak
        let logits = bound.execute(x.data(), m, &mut arena).unwrap();
        assert_eq!(logits.len(), golden.len(), "{name}/{wm:?}/{am:?}");
        for (i, (&a, &g)) in logits.iter().zip(golden.data()).enumerate() {
            assert_eq!(
                a.to_bits(),
                g.to_bits(),
                "{name}/{wm:?}/{am:?} round {round}: logit {i} diverged ({a} vs {g})"
            );
        }
    }
    bound.elided_layers()
}

/// (3) Across all four models × fp/bit/DoReFa × ReLU6 (+ PACT where the
/// model registers PACT entries): planned-arena ≡ tape, bit for bit.
#[test]
fn planned_executor_matches_tape_everywhere() {
    for (si, name) in models::model_names().into_iter().enumerate() {
        let man = manifest_for(name).unwrap();
        let model = models::get(name).unwrap();
        let seed = 100 + si as u64;

        // fp weights, ReLU6 activations (fp_eval_relu6)
        let fp = ModelState::init_fp(&man, seed);
        assert_planned_matches_tape(name, &fp, WMode::Fp, AMode::Relu6, None, false, seed);

        // fp weights, ref (clip-only) activations — the HVP center graph
        assert_planned_matches_tape(name, &fp, WMode::Fp, AMode::Ref, None, false, seed + 1);

        // DoReFa quantized weights (dorefa_eval_relu6)
        let wlv = vec![7.0f32; model.qlayers.len()];
        assert_planned_matches_tape(
            name,
            &fp,
            WMode::Dorefa,
            AMode::Relu6,
            Some(wlv),
            false,
            seed + 2,
        );

        // bit-plane weights on the sparsity-proportional GEMM (q_eval_relu6)
        let mut bit = ModelState::init_fp(&man, seed + 3);
        bit.to_bit_representation(&man, 6).unwrap();
        assert_planned_matches_tape(name, &bit, WMode::Bit, AMode::Relu6, None, true, seed + 3);

        // PACT clip activations where the model registers PACT entries
        if model.entries.iter().any(|e| e.ends_with("_pact")) {
            let mut pact = ModelState::init_fp(&man, seed + 4);
            pact.to_bit_representation(&man, 5).unwrap();
            pact.add_pact(&man);
            assert_planned_matches_tape(name, &pact, WMode::Bit, AMode::Pact, None, true, seed + 4);
        }
    }
}

/// Dead-layer elision: a layer whose planes are fully trimmed is skipped
/// by the planned executor (elision flag set) and still bit-identical to
/// the tape path computing the zero GEMM the long way.
#[test]
fn elided_dead_layer_stays_bit_identical() {
    let man = manifest_for("tinynet").unwrap();
    let mut state = ModelState::init_fp(&man, 42);
    state.to_bit_representation(&man, 6).unwrap();
    for key in ["wp:conv2", "wn:conv2"] {
        state.get_mut(key).unwrap().data_mut().fill(0.0);
    }
    let elided =
        assert_planned_matches_tape("tinynet", &state, WMode::Bit, AMode::Relu6, None, true, 7);
    assert_eq!(elided, 1, "conv2's empty planes must be elided");
}

/// The stable-slot contract behind sharded deposits: graph node ids are
/// construction-time constants, so every (model, entry) resolves the same
/// parameter to the same node across processes and shard counts.
#[test]
fn graph_node_ids_are_stable_across_builds() {
    for name in models::model_names() {
        let model = models::get(name).unwrap();
        let a = models::graph(&model).unwrap();
        let b = models::graph(&model).unwrap();
        assert_eq!(a, b, "{name}: graph construction is not deterministic");
        // ids are dense and topological
        for (i, node) in a.nodes.iter().enumerate() {
            assert!(node.inputs.iter().all(|&p| p < i), "{name}: node {i} breaks topo order");
        }
    }
}

/// The weight maps a bound plan consumes reject double use — the same
/// error contract the old imperative walker had.
#[test]
fn bind_consumes_each_layer_exactly_once() {
    let man = manifest_for("tinynet").unwrap();
    let model = models::get("tinynet").unwrap();
    let plans = ir::plans_for("tinynet").unwrap();
    let state = ModelState::init_fp(&man, 0);
    let actlv = vec![15.0f32; model.act_sites.len()];
    // missing layer → load-time error, not a panic mid-pass
    let mut reps = eval_weights(&model, &state, WMode::Fp, None, false).unwrap();
    reps.remove("conv2");
    let err = ir::bind(&plans.infer, &model, &state, reps, &actlv, AMode::Relu6)
        .unwrap_err()
        .to_string();
    assert!(err.contains("conv2"), "{err}");
    let empty: BTreeMap<String, bsq::runtime::native::tape::WeightRep> = BTreeMap::new();
    assert!(ir::bind(&plans.infer, &model, &state, empty, &actlv, AMode::Relu6).is_err());
}
