//! Differential property tests: packed codes engine ⇄ scalar reference.
//!
//! The packed engine (`quant::packed`, driving `to_bitplanes` /
//! `from_bitplanes` / `integer_codes` / `requantize`) must reproduce the
//! retained scalar path (`quant::reference`) *bit for bit*: identical
//! integer codes, identical binary planes, identical masks, identical f32
//! scale bits, identical reconstructed weights. Anything weaker would let
//! the fast path silently drift from paper Eq. 2 / §3.3 semantics.
//!
//! 520 randomized continuous-plane states plus deterministic edges:
//! precision growth to n+1, capacity clamping, dead layers, LSB-trim
//! cascades, word-boundary element counts, and gapped (non-bottom-packed)
//! masks.

use bsq::quant::bitplane::integer_codes;
use bsq::quant::{
    from_bitplanes, packed_mask, reference, requantize, to_bitplanes, BitRep, NB,
};
use bsq::tensor::Tensor;
use bsq::util::Pcg32;

fn assert_rep_identical(a: &BitRep, b: &BitRep, ctx: &str) {
    assert_eq!(a.wp.shape(), b.wp.shape(), "{ctx}: wp shape");
    assert_eq!(a.wp.data(), b.wp.data(), "{ctx}: wp planes");
    assert_eq!(a.wn.data(), b.wn.data(), "{ctx}: wn planes");
    assert_eq!(a.mask.data(), b.mask.data(), "{ctx}: mask");
    assert_eq!(a.scale.to_bits(), b.scale.to_bits(), "{ctx}: scale bits");
}

fn assert_weights_identical(a: &Tensor, b: &Tensor, ctx: &str) {
    assert_eq!(a.shape(), b.shape(), "{ctx}: shape");
    for (i, (x, y)) in a.data().iter().zip(b.data()).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: weight {i}: {x} vs {y}");
    }
}

/// Full equivalence check of one state: codes, reconstruction, adjustment.
fn check_state(rep: &BitRep, ctx: &str) {
    assert_eq!(integer_codes(rep), reference::integer_codes(rep), "{ctx}: codes");
    assert_weights_identical(&from_bitplanes(rep), &reference::from_bitplanes(rep), ctx);

    let mut fast = rep.clone();
    let mut slow = rep.clone();
    let r_fast = requantize(&mut fast);
    let r_slow = reference::requantize(&mut slow);
    assert_eq!(r_fast, r_slow, "{ctx}: adjust report");
    assert_rep_identical(&fast, &slow, &format!("{ctx}: post-requantize"));
    // and the packed path is a fixed point of itself after adjustment
    assert_eq!(integer_codes(&fast), reference::integer_codes(&slow), "{ctx}: post codes");
}

#[test]
fn prop_packed_matches_reference_across_random_states() {
    let mut rng = Pcg32::seeded(0xB50D1FF);
    for case in 0..520usize {
        let elems = 1 + rng.below(300) as usize;
        let n = 1 + (case % NB);
        let w = Tensor::randn(&[elems], rng.range(0.01, 2.0), &mut rng);

        // conversion itself must agree bit for bit
        let mut rep = reference::to_bitplanes(&w, n).unwrap();
        let rep_fast = to_bitplanes(&w, n).unwrap();
        assert_rep_identical(&rep_fast, &rep, &format!("case {case}: to_bitplanes"));

        // drive the state into one of five mid-training shapes
        match case % 5 {
            0 => {} // freshly converted, exact binary planes
            1 => {
                // generic continuous perturbation
                for v in rep.wp.data_mut().iter_mut().chain(rep.wn.data_mut()) {
                    *v = (*v + rng.range(-0.45, 0.45)).clamp(0.0, 2.0);
                }
            }
            2 => {
                // saturate planes toward 2.0: forces n+1 growth and, at
                // full mask, the ±(2^NB − 1) capacity clamp
                for v in rep.wp.data_mut().iter_mut() {
                    if rng.bool(0.5) {
                        *v = rng.range(1.7, 2.0);
                    }
                }
            }
            3 => {
                // collapse toward zero: many dead layers
                for v in rep.wp.data_mut().iter_mut().chain(rep.wn.data_mut()) {
                    *v = if rng.bool(0.9) { 0.0 } else { rng.range(0.0, 0.4) };
                }
            }
            _ => {
                // gapped, non-bottom-packed mask (reference honors it; the
                // packed path must match), sometimes entirely empty
                let mut m = vec![0.0f32; NB];
                for slot in m.iter_mut() {
                    if rng.bool(0.5) {
                        *slot = 1.0;
                    }
                }
                rep.mask = Tensor::new(vec![NB], m).unwrap();
                for v in rep.wp.data_mut().iter_mut().chain(rep.wn.data_mut()) {
                    *v = (*v + rng.range(-0.3, 0.3)).clamp(0.0, 2.0);
                }
            }
        }
        rep.scale = rng.range(0.01, 4.0);
        check_state(&rep, &format!("case {case} (elems {elems}, n {n})"));
    }
}

#[test]
fn edge_precision_growth_to_n_plus_one() {
    // float planes up to 2.0 push codes past 2^n − 1: n grows to n + 1
    for n in 1..NB {
        let w = Tensor::new(vec![2], vec![0.9, 0.53]).unwrap();
        let mut rep = reference::to_bitplanes(&w, n).unwrap();
        // element 0: every active plane inflated to 1.9 → code round(1.9·(2^n−1))
        // overflows n bits; element 1: pinned to code 1 (odd) so no LSB trim
        // can mask the growth
        for b in 0..NB {
            rep.wp.row_mut(b, 2)[0] = if b < n { 1.9 } else { 0.0 };
            rep.wp.row_mut(b, 2)[1] = if b == 0 { 1.0 } else { 0.0 };
        }
        check_state(&rep, &format!("growth n={n}"));
        let mut adjusted = rep.clone();
        let r = requantize(&mut adjusted);
        assert!(r.bits_after > n, "n={n}: expected growth, got {}", r.bits_after);
    }
}

#[test]
fn edge_capacity_clamp_saturated_planes() {
    let mut rep = reference::to_bitplanes(&Tensor::new(vec![3], vec![0.3, -0.2, 0.1]).unwrap(), 8)
        .unwrap();
    rep.mask = packed_mask(NB);
    rep.wp.data_mut().fill(2.0);
    rep.wn.data_mut().fill(0.0);
    assert_eq!(integer_codes(&rep), vec![(1 << NB) - 1; 3]);
    check_state(&rep, "saturated clamp");
}

#[test]
fn edge_dead_layer() {
    // codes all round to zero → the layer dies identically on both paths
    let w = Tensor::new(vec![5], vec![1.0, 0.001, -0.002, 0.0, 0.001]).unwrap();
    let mut rep = reference::to_bitplanes(&w, 8).unwrap();
    rep.wp.data_mut().fill(0.0);
    rep.wn.data_mut().fill(0.0);
    check_state(&rep, "dead layer");
    let mut adjusted = rep.clone();
    assert_eq!(requantize(&mut adjusted).bits_after, 0);
    // a dead layer stays dead (n = 0 early-return on both paths)
    check_state(&adjusted, "dead layer stays dead");
}

#[test]
fn edge_lsb_trim_cascade() {
    // all codes sharing k trailing zeros, for every k
    for k in 0..=3usize {
        let step = 1i64 << k;
        let codes: Vec<i64> = vec![3 * step, -5 * step, 7 * step, step];
        let (wp, wn) = reference::planes_from_codes(&codes, &[codes.len()], 6);
        let rep = BitRep { wp, wn, mask: packed_mask(6), scale: 1.5 };
        check_state(&rep, &format!("lsb cascade k={k}"));
        let mut adjusted = rep.clone();
        assert_eq!(requantize(&mut adjusted).lsb_trimmed, k);
    }
}

#[test]
fn edge_word_boundary_sizes() {
    // exercise the partial trailing u64 word of the plane bitsets
    let mut rng = Pcg32::seeded(99);
    for elems in [1usize, 63, 64, 65, 127, 128, 129, 256] {
        let w = Tensor::randn(&[elems], 0.5, &mut rng);
        let mut rep = reference::to_bitplanes(&w, 8).unwrap();
        assert_rep_identical(
            &to_bitplanes(&w, 8).unwrap(),
            &rep,
            &format!("boundary {elems}: to_bitplanes"),
        );
        for v in rep.wp.data_mut().iter_mut().chain(rep.wn.data_mut()) {
            *v = (*v + rng.range(-0.4, 0.4)).clamp(0.0, 2.0);
        }
        check_state(&rep, &format!("boundary {elems}"));
    }
}

#[test]
fn pack_bridge_agrees_with_reference_codes() {
    let mut rng = Pcg32::seeded(7);
    for case in 0..50usize {
        let elems = 1 + rng.below(200) as usize;
        let w = Tensor::randn(&[elems], 0.5, &mut rng);
        let mut rep = to_bitplanes(&w, 1 + case % 8).unwrap();
        for v in rep.wp.data_mut().iter_mut().chain(rep.wn.data_mut()) {
            *v = (*v + rng.range(-0.3, 0.3)).clamp(0.0, 2.0);
        }
        let packed = rep.pack();
        let want = reference::integer_codes(&rep);
        assert_eq!(packed.codes.len(), want.len());
        for (a, b) in packed.codes.iter().zip(&want) {
            assert_eq!(*a as i64, *b, "case {case}");
        }
        // unpacking a *requantized* state reproduces the binary rep exactly
        let mut adjusted = rep.clone();
        requantize(&mut adjusted);
        assert_rep_identical(&adjusted.pack().unpack(), &adjusted, &format!("case {case}: unpack"));
    }
}
