//! Steady-state allocation audit for the serving forward pass.
//!
//! The acceptance contract of the planned executor (DESIGN.md §11): once a
//! serving thread's arena has seen a batch size, `ServableModel::infer_into`
//! performs **zero heap allocations** — activations live at planned arena
//! offsets, kernel scratch is grow-only, parameters were resolved at bind
//! time, and a thread GEMM cap of 1 (the saturated serve-pool
//! configuration, workers ≥ cores) keeps the kernels from spawning scoped
//! threads or probing host parallelism.
//!
//! Measured with a counting global allocator. This file deliberately holds
//! a single `#[test]`: the binary runs it alone, so no concurrent test
//! thread can pollute the counter window.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

#[test]
fn serving_forward_pass_is_allocation_free_in_steady_state() {
    use bsq::runtime::Engine;
    use bsq::serve::{synthesize_quantized_checkpoint, ServableModel};
    use bsq::util::Pcg32;

    let engine = Engine::native();
    let dir = std::env::temp_dir().join(format!("bsq_alloc_{}", std::process::id()));
    let ckpt = dir.join("tiny_q.ckpt");
    synthesize_quantized_checkpoint(&engine, "tinynet", 6, 3, &ckpt).unwrap();
    let sv = ServableModel::load(&engine, "tinynet", &ckpt, 4, 8).unwrap();

    // Mirror the saturated serve-pool configuration (workers ≥ cores):
    // each worker's inner GEMM budget is 1, the allocation-free regime.
    bsq::tensor::gemm::set_thread_parallelism_cap(1);

    let m = 4usize;
    let mut rng = Pcg32::seeded(11);
    let x: Vec<f32> = (0..m * sv.sample_elems()).map(|_| rng.normal()).collect();
    let mut out: Vec<f32> = Vec::with_capacity(m * sv.num_classes());

    // Warm pass: grows the thread-local arena + scratch and out's capacity.
    let classes = sv.infer_into(&x, m, &mut out).unwrap();
    assert_eq!(out.len(), m * classes);
    let warm = out.clone();

    // Steady state: the forward pass must not touch the allocator.
    out.clear();
    let before = ALLOCS.load(Ordering::SeqCst);
    sv.infer_into(&x, m, &mut out).unwrap();
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(delta, 0, "steady-state serving forward made {delta} heap allocations");

    // And it still computes the same bits it did on the warm pass.
    assert_eq!(out.len(), warm.len());
    for (i, (a, b)) in out.iter().zip(&warm).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "logit {i} changed across arena reuse");
    }

    // Smaller batches reuse the grown arena allocation-free too.
    let x1 = &x[..sv.sample_elems()];
    out.clear();
    let before = ALLOCS.load(Ordering::SeqCst);
    sv.infer_into(x1, 1, &mut out).unwrap();
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(delta, 0, "batch-1 pass on a warm arena made {delta} allocations");

    std::fs::remove_dir_all(dir).ok();
}
