//! The stale-checkpoint bug, fixed end to end (DESIGN.md §14):
//!
//! 1. **Stale-path regression** — the registry used to key its cache on the
//!    checkpoint *path*, so overwriting a checkpoint kept serving the old
//!    weights forever. Content-digest keying makes the overwrite visible on
//!    the very next load. (This test fails against the old path-keyed
//!    cache.)
//! 2. **Single-flight** — N threads cold-missing the same checkpoint build
//!    its servable exactly once.
//! 3. **Swap under load** — a hot-swap installed mid-run drops zero
//!    requests, duplicates none, and every served response's logits
//!    bitwise-match exactly one of {old, new} — with everything stamped
//!    post-swap matching new.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Barrier};

use bsq::runtime::Engine;
use bsq::serve::{
    self, run_closed_loop_swapped, synthetic_input, BatchPolicy, PoolConfig, Registry,
    ServableModel, ServeStatus, SwapHandle, FIRST_GEN,
};

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bsq_swap_serve_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn synth(engine: &Engine, dir: &std::path::Path, bits: usize, seed: u64) -> PathBuf {
    let path = dir.join(format!("tiny_b{bits}_s{seed}.ckpt"));
    serve::synthesize_quantized_checkpoint(engine, "tinynet", bits, seed, &path).unwrap();
    path
}

/// Single-sample logits straight off a servable, bypassing the pool — the
/// oracle the pool's responses are compared against bit-for-bit.
fn oracle(sv: &ServableModel, seed: u64, client: usize, index: usize) -> Vec<f32> {
    let x = synthetic_input(seed, client, index, sv.sample_elems());
    let mut out = Vec::new();
    sv.infer_into(&x, 1, &mut out).unwrap();
    out
}

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

#[test]
fn overwritten_checkpoint_is_not_served_stale() {
    let engine = Engine::native();
    let dir = scratch("stale");
    let live = dir.join("live.ckpt");

    // deploy A at the path and serve it once
    let a = synth(&engine, &dir, 6, 10);
    std::fs::copy(&a, &live).unwrap();
    let reg = Registry::new(&engine);
    let sv_a = reg.load("tinynet", &live, 4, 8).unwrap();
    let logits_a = oracle(&sv_a, 0, 0, 0);

    // training "redeploys": same path, new bytes
    let b = synth(&engine, &dir, 3, 11);
    std::fs::copy(&b, &live).unwrap();

    // the next load MUST see B — a path-keyed cache would hand back A here
    let sv_b = reg.load("tinynet", &live, 4, 8).unwrap();
    assert!(!Arc::ptr_eq(&sv_a, &sv_b), "cache returned the stale servable");
    assert_ne!(sv_a.weights_digest, sv_b.weights_digest);
    let logits_b = oracle(&sv_b, 0, 0, 0);
    assert!(!bits_eq(&logits_a, &logits_b), "overwritten weights served stale logits");

    // both servables stay addressable — they are different content keys
    assert_eq!(reg.loaded().len(), 2);
    assert_eq!(reg.builds(), 2);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn concurrent_cold_misses_build_exactly_once() {
    let engine = Engine::native();
    let dir = scratch("singleflight");
    let ckpt = synth(&engine, &dir, 6, 20);
    let reg = Registry::new(&engine);

    const THREADS: usize = 8;
    let gate = Barrier::new(THREADS);
    let loaded = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                s.spawn(|| {
                    gate.wait(); // maximize the cold-miss collision
                    reg.load("tinynet", &ckpt, 4, 8).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
    });

    // one build, one resident servable, everyone sharing it
    assert_eq!(reg.builds(), 1, "duplicate builds under concurrent cold miss");
    assert_eq!(reg.loaded().len(), 1);
    for sv in &loaded[1..] {
        assert!(Arc::ptr_eq(&loaded[0], sv));
    }
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn swap_under_load_never_drops_or_mixes_requests() {
    let engine = Engine::native();
    let dir = scratch("underload");
    let reg = Registry::new(&engine);
    let old = reg.load("tinynet", &synth(&engine, &dir, 6, 30), 4, 8).unwrap();
    let new = reg.load("tinynet", &synth(&engine, &dir, 3, 31), 4, 8).unwrap();

    const TOTAL: usize = 512;
    const SEED: u64 = 7;
    let cfg = PoolConfig::new(2, BatchPolicy::new(8, std::time::Duration::from_millis(1)));
    let handle = SwapHandle::new(Arc::clone(&old));
    let swapped_at = AtomicU64::new(0);

    let (stats, responses) = std::thread::scope(|s| {
        let publisher = s.spawn(|| {
            // swap as soon as real traffic exists, so plenty of batches
            // land on each side of the boundary
            while handle.batches_served() < 2 {
                std::hint::spin_loop();
            }
            let gen = handle.swap(Arc::clone(&new)).unwrap();
            swapped_at.store(handle.batches_served().max(1), Ordering::Relaxed);
            gen
        });
        let run = run_closed_loop_swapped(&handle, &cfg, TOTAL, 8, SEED).unwrap();
        assert_eq!(publisher.join().unwrap(), FIRST_GEN + 1);
        run
    });

    // zero dropped, zero duplicated
    assert_eq!(stats.completed, TOTAL);
    assert_eq!(responses.len(), TOTAL);
    let mut seen: Vec<(usize, usize)> = responses.iter().map(|r| (r.client, r.index)).collect();
    seen.sort_unstable();
    seen.dedup();
    assert_eq!(seen.len(), TOTAL, "a request was answered twice");
    assert_eq!(stats.swaps, 1);

    // every response matches exactly one of {old, new}, agreeing with its
    // generation stamp — no torn or mixed-weights batch anywhere
    let mut served_old = 0usize;
    let mut served_new = 0usize;
    for r in &responses {
        assert_eq!(r.status, ServeStatus::Ok);
        let want_old = oracle(&old, SEED, r.client, r.index);
        let want_new = oracle(&new, SEED, r.client, r.index);
        assert!(
            !bits_eq(&want_old, &want_new),
            "test needs distinguishable models (client {} index {})",
            r.client,
            r.index
        );
        match r.model_gen {
            g if g == FIRST_GEN => {
                assert!(bits_eq(&r.logits, &want_old), "gen-1 response not from old weights");
                served_old += 1;
            }
            g if g == FIRST_GEN + 1 => {
                assert!(bits_eq(&r.logits, &want_new), "post-swap response not from new weights");
                served_new += 1;
            }
            g => panic!("response carries unknown generation {g}"),
        }
    }
    // the swap really landed mid-run: traffic on both sides of it
    assert!(served_old > 0, "swap landed before any traffic");
    assert!(served_new > 0, "swap never became visible to the pool");
    assert!(swapped_at.load(Ordering::Relaxed) >= 1);
    std::fs::remove_dir_all(dir).ok();
}
